"""Exactness and behaviour of the three search systems (MESSI / ParIS / UCR)
against each other — the paper's §IV comparisons as correctness tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

import repro.core as core
from repro.core import isax
from repro.core.paris import search_paris, search_flat
from repro.core.index import build_flat
from repro.core.ucr import search_scan
from repro.data import random_walk, sald_like, seismic_like

RNG = np.random.default_rng(7)


def dataset(kind: str, n=1024, length=128):
    if kind == "walk":
        return random_walk(n, length, seed=3)
    if kind == "sald":
        return sald_like(n, length, seed=4)
    return seismic_like(n, length, seed=5)


@pytest.mark.parametrize("kind", ["walk", "sald", "seismic"])
@pytest.mark.parametrize("capacity", [64, 256])
def test_messi_equals_oracle(kind, capacity):
    raw = jnp.asarray(dataset(kind))
    qs = jnp.asarray(dataset(kind)[RNG.choice(1024, 8, replace=False)]
                     + 0.1 * RNG.standard_normal((8, 128)).astype(np.float32))
    idx = core.build(raw, capacity=capacity)
    got = core.search(idx, qs)
    want = search_scan(raw, qs)
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-3, atol=5e-3)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


@pytest.mark.parametrize("kind", ["walk", "sald"])
def test_paris_equals_oracle(kind):
    raw = jnp.asarray(dataset(kind))
    qs = jnp.asarray(dataset(kind)[:6])
    idx = core.build(raw, capacity=128)
    got = search_paris(idx, qs, chunk=256)
    want = search_scan(raw, qs)
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-3, atol=5e-3)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


def test_paris_flat_standalone():
    """ParIS without a block index (pure SAX-array path, as in the paper)."""
    raw = jnp.asarray(dataset("walk", 512))
    qs = jnp.asarray(dataset("walk", 512)[:4])
    fidx = build_flat(raw)
    got = search_flat(fidx, qs, chunk=128)
    want = search_scan(raw, qs)
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-3, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 60),
       st.sampled_from([32, 64, 128]))
def test_messi_exact_hypothesis(seed, n_series, length):
    """Random shapes/seeds: MESSI always returns the true 1-NN."""
    r = np.random.default_rng(seed)
    raw = jnp.asarray(
        np.cumsum(r.standard_normal((n_series, length)), axis=1)
        .astype(np.float32))
    qs = jnp.asarray(
        np.cumsum(r.standard_normal((3, length)), axis=1).astype(np.float32))
    idx = core.build(raw, capacity=8)
    got = core.search(idx, qs, blocks_per_iter=2)
    want = search_scan(raw, qs)
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-3, atol=5e-3)


def test_initial_threshold_seeding_preserves_result():
    """Seeding the pruning bound with a (looser) global threshold must not
    change the result — the distributed round-1 contract."""
    raw = jnp.asarray(dataset("walk", 512))
    qs = jnp.asarray(dataset("walk", 512)[:4])
    idx = core.build(raw, capacity=64)
    base = core.search(idx, qs)
    thr = jnp.asarray(base.dist[:, 0]) ** 2 + 1e-3
    seeded = core.search(idx, qs, initial_threshold=thr)
    np.testing.assert_allclose(np.asarray(seeded.dist),
                               np.asarray(base.dist), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(seeded.idx), np.asarray(base.idx))


def test_lb_filter_toggle_same_answer():
    raw = jnp.asarray(dataset("walk", 512))
    qs = jnp.asarray(dataset("walk", 512)[:4])
    idx = core.build(raw, capacity=64)
    a = core.search(idx, qs, lb_filter=True)
    b = core.search(idx, qs, lb_filter=False)
    np.testing.assert_allclose(np.asarray(a.dist), np.asarray(b.dist),
                               rtol=1e-5, atol=1e-5)
    # with the filter on, strictly fewer (or equal) real distances computed
    assert (np.asarray(a.stats.series_refined)
            <= np.asarray(b.stats.series_refined)).all()


def test_deadline_gives_anytime_upper_bound():
    raw = jnp.asarray(dataset("walk", 2048))
    qs = jnp.asarray(dataset("walk", 2048)[:4] * 1.01)
    idx = core.build(raw, capacity=32)
    exact = core.search(idx, qs)
    rough = core.search(idx, qs, deadline_blocks=2)
    assert (np.asarray(rough.dist) >= np.asarray(exact.dist) - 1e-5).all()
    assert (np.asarray(rough.stats.blocks_visited)
            <= np.asarray(exact.stats.blocks_visited)).all()


def test_deadline_equal_to_block_count_stays_exact():
    """deadline_blocks == n_blocks is the no-op deadline: the while_loop
    cond still evaluates next_lb at ptr == B (logical_and does not
    short-circuit) and must stay in-bounds via the explicit clamp."""
    raw = jnp.asarray(dataset("walk", 2048))
    qs = jnp.asarray(dataset("walk", 2048)[:4] * 1.01)
    idx = core.build(raw, capacity=32)
    exact = core.search(idx, qs)
    capped = core.search(idx, qs, deadline_blocks=idx.n_blocks)
    assert np.array_equal(np.asarray(capped.idx), np.asarray(exact.idx))
    np.testing.assert_allclose(np.asarray(capped.dist),
                               np.asarray(exact.dist), rtol=1e-6, atol=1e-6)
    from repro.core.search import search_block_major
    bm = search_block_major(idx, qs, deadline_blocks=idx.n_blocks)
    assert np.array_equal(np.asarray(bm.idx), np.asarray(exact.idx))


def test_pruning_hierarchy_matches_paper():
    """The paper's claim: MESSI refines fewer series than ParIS, both far
    fewer than the full scan (Fig. 9/12 mechanism)."""
    raw = jnp.asarray(dataset("walk", 4096))
    qs = jnp.asarray(dataset("walk", 4096)[:8] * 1.001)
    idx = core.build(raw, capacity=128)
    messi = core.search(idx, qs)
    paris = search_paris(idx, qs)
    ucr = search_scan(raw, qs)
    m = float(np.mean(np.asarray(messi.stats.series_refined)))
    p = float(np.mean(np.asarray(paris.stats.series_refined)))
    u = float(np.mean(np.asarray(ucr.stats.series_refined)))
    assert m <= p <= u
    assert m < 0.25 * u, f"MESSI refined {m} of {u} — pruning broken?"


def test_batch_of_one_and_many():
    raw = jnp.asarray(dataset("walk", 256))
    idx = core.build(raw, capacity=32)
    one = core.search(idx, raw[:1])
    many = core.search(idx, raw[:16])
    assert int(one.idx[0, 0]) == 0
    assert np.array_equal(np.asarray(many.idx[:, 0]), np.arange(16))
    assert np.allclose(np.asarray(many.dist), 0, atol=1e-2)


@pytest.mark.parametrize("kind", ["walk", "sald", "seismic"])
def test_block_major_equals_oracle(kind):
    from repro.core.search import search_block_major
    raw = jnp.asarray(dataset(kind))
    qs = jnp.asarray(dataset(kind)[RNG.choice(1024, 8, replace=False)]
                     + 0.1 * RNG.standard_normal((8, 128)).astype(np.float32))
    idx = core.build(raw, capacity=64)
    got = search_block_major(idx, qs)
    want = search_scan(raw, qs)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-3, atol=5e-3)
    # seeded variant keeps distances
    seeded = search_block_major(idx, qs,
                                initial_threshold=jnp.asarray(got.dist[:, 0])
                                ** 2 + 1e-3)
    np.testing.assert_allclose(np.asarray(seeded.dist),
                               np.asarray(got.dist), rtol=1e-5, atol=1e-5)
