"""FROZEN pre-engine search drivers — the golden reference for
tests/test_engine.py.

These are verbatim copies of the four device-resident query drivers as
they stood immediately before the `core/engine.py` refactor (PR 4):

  * ``search``             — MESSI query-major (core/search.py)
  * ``search_block_major`` — MESSI block-major (core/search.py)
  * ``search_flat``        — ParIS flat SAX-array scan (core/paris.py)
  * ``search_dtw``         — DTW over the Euclidean index (core/dtw.py)

They depend only on modules the refactor left numerically untouched
(``frontier``, ``isax``, ``index``, ``kernels.ops``), so running them
today reproduces the pre-refactor traced graphs exactly.  The parity
matrix asserts the engine-backed wrappers are BIT-identical to these on
fixed-seed inputs for k in {1, 5, 32}.

Do not "improve" this file: its value is that it does not change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.frontier import Frontier, INF, SearchStats, query_block_l2
from repro.core.index import BlockIndex, FlatIndex, flat_view
from repro.core.search import SearchResult
from repro.kernels import ops

_bound = frontier_lib.bound


def _result(front: Frontier, stats: SearchStats) -> SearchResult:
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


def refine_panel(q, q_paa, front, stats, block, ids_b, lo, hi,
                 active, thr, *, n, w, lb_filter):
    qn, c = q.shape[0], block.shape[0]
    if lb_filter:
        qe = q_paa[:, :, None]                                 # (Q, w, 1)
        dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
        s_lb = (n / w) * jnp.sum(dd * dd, axis=1)              # (Q, C)
        s_act = (s_lb < thr[:, None]) & active[:, None]
    else:
        s_act = jnp.broadcast_to(active[:, None], (qn, c))
    d = ops.batch_l2(q, block)                                 # (Q, C)
    live = s_act & (ids_b >= 0)[None, :]
    d = jnp.where(live, d, INF)
    front = front.insert(d, jnp.where(live, ids_b[None, :], -1))
    stats = SearchStats(
        blocks_visited=stats.blocks_visited + active.astype(jnp.int32),
        series_refined=stats.series_refined
        + jnp.sum(live, axis=1, dtype=jnp.int32),
        lb_series=stats.lb_series
        + (active.astype(jnp.int32) * c if lb_filter else 0),
        iters=stats.iters,
    )
    return front, stats


@functools.partial(jax.jit, static_argnames=("k", "blocks_per_iter",
                                             "lb_filter", "deadline_blocks",
                                             "normalize_queries"))
def search(index: BlockIndex, queries: jax.Array, *, k: int = 1,
           blocks_per_iter: int = 4, lb_filter: bool = True,
           initial_threshold: jax.Array | None = None,
           deadline_blocks: int | None = None,
           normalize_queries: bool = True) -> SearchResult:
    setup = frontier_lib.prepare(queries, k, index=index,
                                 normalize=normalize_queries)
    q, q_paa, front, block_lb, stats0 = setup
    b, c, n = index.raw.shape
    qn = q.shape[0]
    kb = min(blocks_per_iter, b)

    order = jnp.argsort(block_lb, axis=1)                     # (Q, B)
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def next_lb(ptr):
        safe = jnp.minimum(ptr, b - 1)
        nxt = jax.lax.dynamic_slice_in_dim(order, safe, 1, axis=1)  # (Q,1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]     # (Q,)

    def cond(state):
        ptr, f, _ = state
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(next_lb(ptr)
                                       < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, kb, axis=1)  # (Q,K)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)            # (Q,K)
        active = lbs < thr[:, None]                                  # (Q,K)

        def refine(carry):
            f_i, st_i = carry
            blocks = index.raw[idxs]                                # (Q,K,C,n)
            ids = index.ids[idxs]                                   # (Q,K,C)
            if lb_filter:
                lo = index.slo[idxs]                                # (Q,K,w,C)
                hi = index.shi[idxs]
                qe = q_paa[:, None, :, None]                        # (Q,1,w,1)
                dd = jnp.maximum(jnp.maximum(lo - qe, qe - hi), 0.0)
                s_lb = (n / index.w) * jnp.sum(dd * dd, axis=2)     # (Q,K,C)
                s_act = (s_lb < thr[:, None, None]) & active[..., None]
            else:
                s_act = jnp.broadcast_to(active[..., None], ids.shape)
            d = query_block_l2(q, blocks)                           # (Q,K,C)
            live = s_act & (ids >= 0)
            d = jnp.where(live, d, INF)
            f_n = f_i.insert(d.reshape(qn, -1),
                             jnp.where(live, ids, -1).reshape(qn, -1))
            st_n = SearchStats(
                blocks_visited=st_i.blocks_visited
                + jnp.sum(active, axis=1, dtype=jnp.int32),
                series_refined=st_i.series_refined
                + jnp.sum(live, axis=(1, 2), dtype=jnp.int32),
                lb_series=st_i.lb_series
                + (jnp.sum(active, axis=1, dtype=jnp.int32) * c
                   if lb_filter else 0),
                iters=st_i.iters,
            )
            return f_n, st_n

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + kb, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return _result(front, stats)


@functools.partial(jax.jit, static_argnames=("k", "lb_filter",
                                             "deadline_blocks",
                                             "normalize_queries"))
def search_block_major(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                       lb_filter: bool = True,
                       initial_threshold: jax.Array | None = None,
                       deadline_blocks: int | None = None,
                       normalize_queries: bool = True) -> SearchResult:
    setup = frontier_lib.prepare(queries, k, index=index,
                                 normalize=normalize_queries)
    q, q_paa, front, block_lb, stats0 = setup
    b, c, n = index.raw.shape

    order = jnp.argsort(jnp.min(block_lb, axis=0))            # (B,)
    sched_lb = block_lb[:, order]                             # (Q, B)
    suffix = jax.lax.cummin(sched_lb[:, ::-1], axis=1)[:, ::-1]
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def cond(state):
        ptr, f, _ = state
        safe = jnp.minimum(ptr, b - 1)
        live = jax.lax.dynamic_slice_in_dim(suffix, safe, 1, axis=1)[:, 0]
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(live < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        b_id = order[ptr]
        lbs = jax.lax.dynamic_slice_in_dim(block_lb, b_id, 1, axis=1)[:, 0]
        active = lbs < thr                                    # (Q,)

        def refine(cr):
            f_i, st_i = cr
            block = jax.lax.dynamic_index_in_dim(index.raw, b_id, 0,
                                                 keepdims=False)   # (C, n)
            ids_b = jax.lax.dynamic_index_in_dim(index.ids, b_id, 0,
                                                 keepdims=False)   # (C,)
            lo = hi = None
            if lb_filter:
                lo = jax.lax.dynamic_index_in_dim(index.slo, b_id, 0,
                                                  keepdims=False)  # (w, C)
                hi = jax.lax.dynamic_index_in_dim(index.shi, b_id, 0,
                                                  keepdims=False)
            return refine_panel(q, q_paa, f_i, st_i, block, ids_b, lo, hi,
                                active, thr, n=n, w=index.w,
                                lb_filter=lb_filter)

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + 1, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return _result(front, stats)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def search_flat(index: FlatIndex, queries: jax.Array, *, k: int = 1,
                block_index: BlockIndex | None = None,
                initial_threshold: jax.Array | None = None,
                chunk: int = 4096) -> SearchResult:
    setup = frontier_lib.prepare(queries, k, index=block_index, w=index.w)
    q, q_paa = setup.q, setup.q_paa
    npad, n = index.raw.shape
    qn = q.shape[0]
    c = min(chunk, npad)
    pad = (-npad) % c

    lo, hi, raw, ids = index.lo, index.hi, index.raw, index.ids
    if pad:
        lo = jnp.concatenate([lo, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        hi = jnp.concatenate([hi, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        raw = jnp.concatenate(
            [raw, jnp.full((pad, n), 1.0e4, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], 0)

    lb = ops.lb_scan_planar(q_paa, lo, hi, n=n)               # (Q, Np+pad)

    nchunks = raw.shape[0] // c
    raw_c = raw.reshape(nchunks, c, n)
    ids_c = ids.reshape(nchunks, c)
    lb_c = lb.reshape(qn, nchunks, c)

    def step(carry, inp):
        front, refined = carry
        raw_k, ids_k, lb_k = inp                              # (C,n),(C,),(Q,C)
        thr = frontier_lib.bound(front, initial_threshold)
        act = (lb_k < thr[:, None]) & (ids_k[None, :] >= 0)

        def refine(cr):
            front_j, refined_j = cr
            d = ops.batch_l2(q, raw_k)                        # (Q, C)
            d = jnp.where(act, d, INF)
            front_n = front_j.insert(d, jnp.where(act, ids_k[None, :], -1))
            return (front_n,
                    refined_j + jnp.sum(act, axis=1, dtype=jnp.int32))

        carry = jax.lax.cond(jnp.any(act), refine, lambda cr: cr,
                             (front, refined))
        return carry, None

    (front, refined), _ = jax.lax.scan(
        step, (setup.frontier, jnp.zeros((qn,), jnp.int32)),
        (raw_c, ids_c, jnp.moveaxis(lb_c, 1, 0)))

    stats = SearchStats(
        blocks_visited=jnp.full((qn,), nchunks, jnp.int32),
        series_refined=refined,
        lb_series=jnp.full((qn,), index.n_real, jnp.int32),   # whole array
        iters=jnp.asarray(nchunks, jnp.int32),
    )
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


def search_paris(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                 chunk: int = 4096,
                 initial_threshold: jax.Array | None = None) -> SearchResult:
    return search_flat(flat_view(index), queries, k=k, block_index=index,
                       chunk=chunk, initial_threshold=initial_threshold)


def _query_envelope(q: jax.Array, r: int):
    n = q.shape[-1]
    pads = [(0, 0)] * (q.ndim - 1) + [(r, r)]
    qu = jnp.pad(q, pads, constant_values=-jnp.inf)
    ql = jnp.pad(q, pads, constant_values=jnp.inf)
    iu = jnp.arange(n)[:, None] + jnp.arange(2 * r + 1)[None, :]
    u = jnp.max(qu[..., iu], axis=-1)
    l = jnp.min(ql[..., iu], axis=-1)
    return u, l


def _dtw_band(a: jax.Array, b: jax.Array, r: int) -> jax.Array:
    a, b = jnp.broadcast_arrays(a, b)
    n = a.shape[-1]
    i_idx = jnp.arange(n)

    def diag_cost(k):
        j = k - i_idx
        valid = (j >= 0) & (j < n) & (jnp.abs(i_idx - j) <= r)
        jc = jnp.clip(j, 0, n - 1)
        c = (a[..., i_idx] - jnp.take(b, jc, axis=-1)) ** 2
        return jnp.where(valid, c, INF)

    def shift_down(d):
        return jnp.concatenate([jnp.full(d.shape[:-1] + (1,), INF),
                                d[..., :-1]], axis=-1)

    def body(carry, k):
        prev, prev2 = carry
        c = diag_cost(k)
        best = jnp.minimum(jnp.minimum(prev, shift_down(prev)),
                           shift_down(prev2))
        cur = c + jnp.where(k == 0, 0.0, best)
        cur = jnp.minimum(cur, INF)
        return (cur, prev), None

    init_shape = a.shape[:-1] + (n,)
    prev = jnp.full(init_shape, INF)
    prev2 = jnp.full(init_shape, INF)
    (last, second), _ = jax.lax.scan(body, (prev, prev2),
                                     jnp.arange(2 * n - 1))
    return last[..., n - 1]


def _envelope_block_lb(index: BlockIndex, u_paa, l_paa) -> jax.Array:
    n = index.n
    big = isax.SENTINEL
    w, b = index.elo.shape
    above = ops.lb_scan_planar(u_paa, index.elo,
                               jnp.full((w, b), big, jnp.float32), n=n)
    below = ops.lb_scan_planar(l_paa, jnp.full((w, b), -big, jnp.float32),
                               index.ehi, n=n)
    return above + below


@functools.partial(jax.jit, static_argnames=("r", "k", "blocks_per_iter"))
def search_dtw(index: BlockIndex, queries: jax.Array, *, r: int, k: int = 1,
               blocks_per_iter: int = 2) -> SearchResult:
    q = isax.znorm(queries).astype(jnp.float32)
    qn = q.shape[0]
    b, c, n = index.raw.shape
    u, l = _query_envelope(q, r)
    u_paa, l_paa = isax.paa(u, index.w), isax.paa(l, index.w)

    block_lb = _envelope_block_lb(index, u_paa, l_paa)         # (Q, B)

    b0 = jnp.argmin(block_lb, axis=1)
    blocks0 = index.raw[b0]                                    # (Q, C, n)
    d0 = _dtw_band(q[:, None, :], blocks0, r)                  # (Q, C)
    front = frontier_lib.init(qn, k).insert(d0, index.ids[b0])

    order = jnp.argsort(block_lb, axis=1)
    kb = min(blocks_per_iter, b)

    def next_lb(ptr):
        nxt = jax.lax.dynamic_slice_in_dim(order, ptr, 1, axis=1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]

    def cond(state):
        ptr, f, _ = state
        return jnp.logical_and(ptr < b, jnp.any(next_lb(ptr) < f.threshold()))

    def body(state):
        ptr, f, visited = state
        thr = f.threshold()
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, kb, axis=1)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)
        active = lbs < thr[:, None]

        def refine(cr):
            f_i, visited_i = cr
            blocks = index.raw[idxs]                           # (Q,K,C,n)
            ids = index.ids[idxs]
            above = jnp.maximum(blocks - u[:, None, None, :], 0.0)
            below = jnp.maximum(l[:, None, None, :] - blocks, 0.0)
            dd = above + below
            lbk = jnp.sum(dd * dd, axis=-1)                    # (Q,K,C)
            s_act = (lbk < thr[:, None, None]) & active[..., None] \
                    & (ids >= 0)
            d = _dtw_band(q[:, None, None, :], blocks, r)      # (Q,K,C)
            d = jnp.where(s_act, d, INF)
            f_n = f_i.insert(d.reshape(qn, -1),
                             jnp.where(s_act, ids, -1).reshape(qn, -1))
            return (f_n,
                    visited_i + jnp.sum(active, axis=1, dtype=jnp.int32))

        f_n, visited_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, visited))
        return ptr + kb, f_n, visited_n

    ptr0 = jnp.zeros((), jnp.int32)
    visited0 = jnp.zeros((qn,), jnp.int32)
    _, front, visited = jax.lax.while_loop(
        cond, body, (ptr0, front, visited0))

    stats = SearchStats(blocks_visited=visited,
                        series_refined=visited * c,
                        lb_series=visited * c,
                        iters=jnp.zeros((), jnp.int32))
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)
