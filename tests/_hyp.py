"""Optional-hypothesis shim.

``hypothesis`` is a dev dependency (see requirements.txt / pyproject.toml);
when it is missing the property tests must SKIP, not abort collection of
the whole suite.  Import ``given``/``settings``/``st`` from here instead of
from ``hypothesis``: with the package installed they are the real thing;
without it ``@given(...)`` becomes a ``pytest.mark.skip`` decorator and
``st``/``settings`` become inert stand-ins that absorb any decoration-time
usage (strategy construction, ``@st.composite``, ...).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Inert:
        """Absorbs any attribute access / call made while building strategies."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Inert()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -r requirements.txt)")

    def settings(*args, **kwargs):
        return lambda fn: fn
