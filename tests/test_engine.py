"""Golden parity matrix for the query engine (core/engine.py).

The engine-backed public wrappers must be BIT-identical — dist, idx,
AND stats — to the frozen pre-refactor drivers (tests/_legacy_drivers.py)
on every previously existing metric x schedule x backend cell, for
k in {1, 5, 32} (including k > n_real padding).  The three matrix cells
the engine newly unlocks check exactness against their oracle paths:

  * out-of-core DTW        vs in-memory ``search_dtw``
  * distributed out-of-core vs single-device out-of-core (and the scan)
  * session-served cosine   vs ``vector.search_vectors``
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _legacy_drivers as legacy
import repro.core as core
from repro import storage
from repro.core import distributed, dtw as D, engine, vector
from repro.core.paris import search_flat, search_paris
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.data import random_walk

KS = (1, 5, 32)
R = 4    # DTW band


def _bitwise(got, want, stats=True):
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    assert np.array_equal(np.asarray(got.dist), np.asarray(want.dist))
    if stats:
        for g, w in zip(got.stats, want.stats):
            assert np.array_equal(np.asarray(g), np.asarray(w))


def _exact(got, want):
    """Exactness for cross-backend cells: identical neighbour sets; the
    distances may differ in final ulps between the panel and gathered
    distance kernels."""
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-6, atol=1e-6)


@pytest.fixture(scope="module")
def data():
    raw = random_walk(1024, 128, seed=13)
    rng = np.random.default_rng(29)
    qs = jnp.asarray(raw[rng.choice(1024, 6, replace=False)]
                     + 0.1 * rng.standard_normal((6, 128))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def idx(data):
    raw, _ = data
    return core.build(jnp.asarray(raw), capacity=64)


@pytest.fixture(scope="module")
def tiny():
    """20 real series: k=32 exercises the (INF, -1) padding rows."""
    raw = random_walk(20, 64, seed=5)
    qs = jnp.asarray(raw[:3] * 1.01)
    return core.build(jnp.asarray(raw), capacity=8), qs


@pytest.fixture(scope="module")
def opened(data, tmp_path_factory):
    raw, _ = data
    path = tmp_path_factory.mktemp("engine") / "full.dsix"
    storage.save_index(core.build(jnp.asarray(raw), capacity=64), path)
    return storage.open_index(path)


# ---------------------------------------------------------------------------
# previously existing cells: bit-identical to the frozen drivers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", KS)
def test_parity_ed_query_major(idx, data, k):
    _, qs = data
    _bitwise(core.search(idx, qs, k=k), legacy.search(idx, qs, k=k))


@pytest.mark.parametrize("k", KS)
def test_parity_ed_block_major(idx, data, k):
    _, qs = data
    _bitwise(search_block_major(idx, qs, k=k),
             legacy.search_block_major(idx, qs, k=k))


@pytest.mark.parametrize("k", KS)
def test_parity_ed_flat(idx, data, k):
    _, qs = data
    _bitwise(search_paris(idx, qs, k=k, chunk=256),
             legacy.search_paris(idx, qs, k=k, chunk=256))


def test_parity_ed_flat_standalone(data):
    """ParIS without a block index: empty-frontier start, no stage A."""
    raw, qs = data
    fidx = core.build_flat(jnp.asarray(raw))
    _bitwise(search_flat(fidx, qs, k=5, chunk=200),
             legacy.search_flat(fidx, qs, k=5, chunk=200))


def test_parity_ed_knob_sweep(idx, data):
    """The tuning knobs trace distinct graphs — pin each variant."""
    _, qs = data
    thr = jnp.asarray(core.search(idx, qs, k=1).dist[:, 0]) ** 2 + 1e-3
    for kw in (dict(lb_filter=False), dict(deadline_blocks=3),
               dict(blocks_per_iter=2), dict(initial_threshold=thr)):
        _bitwise(core.search(idx, qs, k=5, **kw),
                 legacy.search(idx, qs, k=5, **kw))
    for kw in (dict(lb_filter=False), dict(deadline_blocks=3),
               dict(initial_threshold=thr)):
        _bitwise(search_block_major(idx, qs, k=5, **kw),
                 legacy.search_block_major(idx, qs, k=5, **kw))


@pytest.mark.parametrize("k", KS)
def test_parity_dtw_query_major(data, k):
    raw, qs = data
    idx = core.build(jnp.asarray(raw[:512]), capacity=64)
    _bitwise(D.search_dtw(idx, qs, r=R, k=k),
             legacy.search_dtw(idx, qs, r=R, k=k))


@pytest.mark.parametrize("k", KS)
def test_parity_cosine_device(data, k):
    """search_vectors == the legacy ED driver on prepped embeddings."""
    rng = np.random.default_rng(3)
    embs = jnp.asarray(rng.standard_normal((1024, 64)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
    vidx = vector.build_vector_index(embs, capacity=64)
    _bitwise(vector.search_vectors(vidx, qs, k=k),
             legacy.search(vidx, vector.prep_vectors(qs), k=k,
                           normalize_queries=False))


@pytest.mark.parametrize("k", (1, 32))
def test_parity_padding_k_gt_n_real(tiny, k):
    """k > n_real: the padding rows (INF dist, id -1) match bit-for-bit."""
    tidx, qs = tiny
    _bitwise(core.search(tidx, qs, k=k), legacy.search(tidx, qs, k=k))
    _bitwise(search_block_major(tidx, qs, k=k),
             legacy.search_block_major(tidx, qs, k=k))
    _bitwise(search_paris(tidx, qs, k=k, chunk=8),
             legacy.search_paris(tidx, qs, k=k, chunk=8))
    _bitwise(D.search_dtw(tidx, qs, r=R, k=k),
             legacy.search_dtw(tidx, qs, r=R, k=k))
    if k > 20:
        got = core.search(tidx, qs, k=k)
        assert np.all(np.asarray(got.idx)[:, 20:] == -1)


@pytest.mark.parametrize("k", KS)
def test_parity_ed_cached_backend(data, opened, k):
    """The cached walk answers exactly what the scan answers — the
    pre-refactor session contract (storage tests pin the I/O side)."""
    raw, qs = data
    got = storage.ooc_search(opened, qs, k=k)
    want = search_scan(jnp.asarray(raw), qs, k=k)
    _bitwise(got, want, stats=False)


# ---------------------------------------------------------------------------
# new cells: exactness against their oracle paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", KS)
def test_new_cell_ooc_dtw(data, opened, k):
    """DTW metric x cached backend == in-memory search_dtw."""
    raw, qs = data
    mem = D.search_dtw(core.build(jnp.asarray(raw), capacity=64),
                       qs, r=R, k=k)
    ooc = storage.ooc_search(opened, qs, k=k, metric=engine.DTW(r=R))
    _exact(ooc, mem)
    # each block is read at most once (DTW envelope bounds can be loose
    # enough on random walks that no block is pruned outright at k=1)
    assert ooc.io.blocks_fetched <= ooc.io.blocks_total
    assert ooc.io.bytes_read <= ooc.io.bytes_scan


@pytest.mark.parametrize("k", KS)
def test_new_cell_session_cosine(tmp_path, k):
    """Cosine metric x cached backend == device search_vectors."""
    rng = np.random.default_rng(3)
    embs = jnp.asarray(rng.standard_normal((1024, 64)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
    vidx = vector.build_vector_index(embs, capacity=64)
    path = tmp_path / "vec.dsix"
    storage.save_index(vidx, path)
    dev = vector.search_vectors(vidx, qs, k=k)
    with storage.SearchSession(storage.open_index(path),
                               cache_blocks=8) as sess:
        ses = sess.search(qs, k=k, metric=engine.Cosine())
    _exact(ses, dev)


def _shard_sessions(raw, tmp_path, n_shards=2, cache_blocks=8):
    n = len(raw) // n_shards
    sessions = []
    for s in range(n_shards):
        ids = jnp.arange(s * n, (s + 1) * n, dtype=jnp.int32)
        sidx = core.build(jnp.asarray(raw[s * n:(s + 1) * n]),
                          capacity=64, ids=ids)
        path = tmp_path / f"shard{s}.dsix"
        storage.save_index(sidx, path)
        sessions.append(storage.SearchSession(
            storage.open_index(path), cache_blocks=cache_blocks))
    return sessions


@pytest.mark.parametrize("k", KS)
def test_new_cell_distributed_ooc(data, opened, tmp_path, k):
    """Two-round protocol over per-shard sessions == single-device ooc
    (and the scan oracle) — disjoint shards, global ids."""
    raw, qs = data
    sessions = _shard_sessions(raw, tmp_path)
    try:
        got = distributed.search_sharded_ooc(sessions, qs, k=k)
    finally:
        for s in sessions:
            s.close()
    single = storage.ooc_search(opened, qs, k=k)
    _exact(got, single)
    _exact(got, search_scan(jnp.asarray(raw), qs, k=k))


def test_distributed_ooc_threshold_tightens_reads(data, tmp_path):
    """Round 1's global pmin bound must not cost MORE disk than running
    the shards blind — the reason the protocol exists."""
    raw, qs = data
    (tmp_path / "a").mkdir()
    (tmp_path / "b").mkdir()
    seeded = _shard_sessions(raw, tmp_path / "a")
    blind = _shard_sessions(raw, tmp_path / "b")
    try:
        res = distributed.search_sharded_ooc(seeded, qs, k=5)
        blind_reads = sum(s.search(qs, k=5).io.blocks_fetched
                          for s in blind)
    finally:
        for s in seeded + blind:
            s.close()
    assert res.io.blocks_fetched <= blind_reads
    assert res.io.cache_hits >= 0 and res.io.blocks_total > 0


# ---------------------------------------------------------------------------
# plan/axis validation
# ---------------------------------------------------------------------------

def test_plan_rejects_unknown_schedule():
    with pytest.raises(ValueError, match="schedule"):
        engine.QueryPlan(schedule="priority_queue")


def test_run_rejects_flat_plan(idx, data):
    _, qs = data
    with pytest.raises(ValueError, match="run_flat"):
        engine.run(idx, qs, engine.QueryPlan(schedule="flat"))


def test_run_cached_requires_block_major(opened, data):
    _, qs = data
    with pytest.raises(ValueError, match="block-major"):
        engine.run_cached(opened, qs,
                          engine.QueryPlan(schedule="query_major"),
                          fetch=lambda b: None)


def test_run_refuses_out_of_core_index(opened, data):
    _, qs = data
    with pytest.raises(ValueError, match="out-of-core"):
        engine.run(opened, qs, engine.QueryPlan())


def test_plan_rejects_nonpositive_deadline():
    for bad in (0, -3):
        with pytest.raises(ValueError, match="deadline_blocks"):
            engine.QueryPlan(deadline_blocks=bad)


def test_run_cached_deadline_cuts_then_resumes_exact(opened, data):
    """A deadline-cut walk returns a resumable state whose continuation
    lands bit-identically on the exact answer (frontier AND cumulative
    stats), refining only the deferred blocks."""
    _, qs = data

    def fetch(b):
        return jax.device_put(opened.host_raw.fetch(b))

    plan = engine.QueryPlan(schedule="block_major", k=5)
    cut_plan = engine.QueryPlan(schedule="block_major", k=5,
                                deadline_blocks=2)
    front, _, state = engine.run_cached(opened, qs, cut_plan, fetch=fetch)
    ref_front, ref_stats, ref_state = engine.run_cached(opened, qs, plan,
                                                        fetch=fetch)
    assert state.refined < ref_state.refined     # strictly fewer blocks
    got_front, got_stats, _ = engine.run_cached(opened, qs, plan,
                                                fetch=fetch, prepared=state)
    assert np.array_equal(np.asarray(got_front.dists),
                          np.asarray(ref_front.dists))
    assert np.array_equal(np.asarray(got_front.ids),
                          np.asarray(ref_front.ids))
    assert np.array_equal(np.asarray(got_stats.blocks_visited),
                          np.asarray(ref_stats.blocks_visited))
    assert np.array_equal(np.asarray(got_stats.series_refined),
                          np.asarray(ref_stats.series_refined))
