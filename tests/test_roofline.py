"""Loop-aware HLO analysis: validated against analytic FLOP counts and XLA's
own cost model on loop-free programs; collective parsing under 8 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.launch import hlo_analysis as H


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    comp = _compile(lambda a, b: a @ b, a, b)
    t = H.analyze_text(comp.as_text())
    assert t.dot_flops == 2 * 256 * 512 * 128
    ca = comp.cost_analysis()
    if isinstance(ca, list):                 # older jax returns [dict]
        ca = ca[0]
    assert t.dot_flops == float(ca["flops"])


def test_scan_flops_multiplied():
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    t = H.analyze_text(_compile(f, ws, x).as_text())
    assert t.dot_flops == 7 * 2 * 8 * 64 * 64
    assert not t.warnings


def test_nested_scan_flops():
    def f(ws, x):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    t = H.analyze_text(_compile(f, ws, x).as_text())
    assert t.dot_flops == 7 * 3 * 2 * 8 * 64 * 64


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    comp = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    t = H.analyze_text(comp.as_text())
    assert t.dot_flops == 2 * 4 * 32 * 64 * 16


def test_dynamic_while_flagged():
    def f(x):
        def cond(s):
            return jnp.sum(s) < 100.0
        def body(s):
            return s @ s
        return jax.lax.while_loop(cond, body, x)
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    t = H.analyze_text(_compile(f, x).as_text())
    assert t.warnings, "dynamic while should be flagged"


def test_bytes_scale_with_tensor_size():
    a1 = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a2 = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    f = lambda a: jnp.tanh(a) * 2 + 1
    t1 = H.analyze_text(_compile(f, a1).as_text())
    t2 = H.analyze_text(_compile(f, a2).as_text())
    assert 10 <= t2.bytes / t1.bytes <= 22          # ~16x data, fusion noise


def test_collective_bytes_parsed():
    run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.launch import hlo_analysis as H
mesh = jax.make_mesh((8,), ("d",))

def f(x):
    return shard_map(lambda a: jax.lax.psum(a, "d"), mesh=mesh,
                     in_specs=P("d"), out_specs=P())(x)
x = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
comp = jax.jit(f).lower(x).compile()
t = H.analyze_text(comp.as_text())
assert "all-reduce" in t.coll_by_op, t.coll_by_op
# per-device tensor is (1, 1024) f32 = 4096 B; all-reduce counts 2x
assert t.coll_by_op["all-reduce"] == 2 * 4096, t.coll_by_op
print("OK")
""")


def test_roofline_terms_and_bottleneck():
    from repro.launch import roofline as rl
    a = jax.ShapeDtypeStruct((2048, 2048), jnp.float32)
    comp = _compile(lambda a: a @ a, a)
    r = rl.analyze(comp, n_chips=1, model_flops=2 * 2048 ** 3)
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.useful_ratio - 1.0) < 0.1
    assert r.compute_s == r.flops / rl.PEAK_FLOPS


def test_model_flops_for_shapes():
    from repro.launch.roofline import model_flops_for
    from repro.configs import get_config, active_params
    cfg = get_config("h2o-danube-1.8b")
    n = active_params(cfg)
    assert model_flops_for(cfg, "train_4k") == 6.0 * n * 4096 * 256
    assert model_flops_for(cfg, "decode_32k") == 2.0 * n * 128
