"""On-disk index subsystem: format round-trip, out-of-core build parity,
streaming search exactness + bytes-read accounting (DESIGN.md §5)."""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from repro import storage
from repro.core.ucr import search_scan
from repro.data import random_walk

# near-zero self-distances carry O(sqrt(eps)) noise in the expanded-form
# L2 (see kernels/batch_l2.py / test_index.py), hence the absolute term
DIST_TOL = dict(rtol=1e-5, atol=2e-2)


@pytest.fixture(scope="module")
def dataset():
    raw = random_walk(4000, 128, seed=31)
    rng = np.random.default_rng(5)
    qs = jnp.asarray(raw[rng.choice(4000, 6, replace=False)]
                     + 0.05 * rng.standard_normal((6, 128))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def saved(dataset, tmp_path_factory):
    raw, _ = dataset
    idx = core.build(jnp.asarray(raw), capacity=128)
    path = tmp_path_factory.mktemp("idx") / "synthetic.dsix"
    storage.save_index(idx, path, extra={"dataset": "rw4000"})
    return idx, path


def test_save_load_roundtrip_bit_identical_result(dataset, saved):
    _, qs = dataset
    idx, path = saved
    loaded = storage.load_index(path)
    for k in (1, 5):
        a = core.search(idx, qs, k=k)
        b = core.search(loaded, qs, k=k)
        assert np.array_equal(np.asarray(a.idx), np.asarray(b.idx))
        assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist))


def test_saved_fields_bit_identical(saved):
    idx, path = saved
    loaded = storage.load_index(path)
    for f in ("raw", "slo", "shi", "elo", "ehi", "ids"):
        assert np.array_equal(np.asarray(getattr(idx, f)),
                              np.asarray(getattr(loaded, f))), f
    for f in ("n", "w", "card", "capacity", "n_real"):
        assert getattr(idx, f) == getattr(loaded, f), f


def test_meta_and_extra(saved):
    _, path = saved
    meta = storage.read_meta(path)
    assert meta["extra"] == {"dataset": "rw4000"}
    assert meta["version"] == 2          # v2: kind field (pipeline files)
    assert meta["kind"] == "index"
    # raw is last and page-aligned: the memmap window is one aligned span
    raw_off = meta["sections"]["raw"]["offset"]
    assert (meta["data_start"] + raw_off) % 4096 == 0
    assert raw_off >= max(s["offset"] for n, s in meta["sections"].items()
                          if n != "raw")


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "junk.dsix"
    p.write_bytes(b"NOPE" + b"\0" * 64)
    with pytest.raises(ValueError, match="magic"):
        storage.read_meta(p)


def test_open_index_is_out_of_core(dataset, saved):
    _, qs = dataset
    _, path = saved
    opened = storage.open_index(path)
    assert not opened.device_resident
    assert opened.raw.shape[1] == 0              # no raw bytes on device
    assert opened.host_raw is not None
    assert isinstance(opened.host_raw.blocks, np.memmap)
    # the in-memory paths must refuse it, pointing at ooc_search
    with pytest.raises(ValueError, match="ooc_search"):
        core.search(opened, qs)
    with pytest.raises(ValueError, match="out-of-core"):
        core.index.flat_view(opened)
    with pytest.raises(ValueError, match="out-of-core"):
        storage.save_index(opened, path)


@pytest.mark.parametrize("k", [1, 5, 32])
def test_ooc_search_oracle_parity(dataset, saved, k):
    raw, qs = dataset
    _, path = saved
    opened = storage.open_index(path)
    res = storage.ooc_search(opened, qs, k=k)
    want = search_scan(jnp.asarray(raw), qs, k=k)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(res.dist), np.asarray(want.dist),
                               **DIST_TOL)


def test_ooc_search_k_exceeds_n_real(tmp_path):
    raw = random_walk(20, 64, seed=9)
    store = storage.SeriesStore.write(tmp_path / "tiny.f32", raw)
    opened = storage.build_on_disk(store, tmp_path / "tiny.dsix", capacity=8)
    qs = jnp.asarray(raw[:3])
    res = storage.ooc_search(opened, qs, k=32)
    want = search_scan(jnp.asarray(raw), qs, k=32)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
    assert (np.asarray(res.idx)[:, 20:] == -1).all()   # padded tail


def test_ooc_build_matches_in_memory_build_bitwise(tmp_path):
    """The acceptance property: a file-built index is byte-equivalent to
    save_index(core.build(...)) on the same data."""
    raw = random_walk(1500, 128, seed=13)
    store = storage.SeriesStore.write(tmp_path / "s.f32", raw)
    storage.build_on_disk(store, tmp_path / "ooc.dsix", capacity=64,
                          chunk=400)
    idx_mem = core.build(jnp.asarray(raw), capacity=64)
    idx_ooc = storage.load_index(tmp_path / "ooc.dsix")
    for f in ("raw", "slo", "shi", "elo", "ehi", "ids"):
        assert np.array_equal(np.asarray(getattr(idx_mem, f)),
                              np.asarray(getattr(idx_ooc, f))), f


def test_ooc_end_to_end_exact_and_reads_fewer_bytes(tmp_path):
    """File -> ooc_build -> ooc_search: identical k-NN to search.search on
    the same data, while reading strictly fewer raw bytes than a scan."""
    raw = random_walk(20000, 256, seed=42)
    rng = np.random.default_rng(7)
    qs = jnp.asarray(raw[rng.choice(20000, 4, replace=False)]
                     + 0.05 * rng.standard_normal((4, 256))
                     .astype(np.float32))
    store = storage.SeriesStore.write(tmp_path / "s.f32", raw)
    opened = storage.build_on_disk(store, tmp_path / "s.dsix", capacity=256,
                                   chunk=4096)
    res = storage.ooc_search(opened, qs, k=5)
    want = core.search(core.build(jnp.asarray(raw), capacity=256), qs, k=5)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(res.dist), np.asarray(want.dist),
                               **DIST_TOL)
    assert res.io.bytes_read < res.io.bytes_scan
    assert res.io.bytes_scan == 20000 * 256 * 4
    assert 0 < res.io.blocks_fetched <= res.io.blocks_total


def test_ooc_search_requires_host_raw(dataset):
    raw, qs = dataset
    idx = core.build(jnp.asarray(raw), capacity=128)
    with pytest.raises(ValueError, match="host_raw"):
        storage.ooc_search(idx, qs)


def test_series_store_roundtrip(tmp_path):
    raw = random_walk(100, 32, seed=3)
    store = storage.SeriesStore.write(tmp_path / "x.f32", raw)
    assert len(store) == 100 and store.length == 32
    np.testing.assert_array_equal(store.read(10, 20), raw[10:20])
    np.testing.assert_array_equal(np.asarray(store.memmap()), raw)
    with pytest.raises(ValueError, match="multiple"):
        storage.SeriesStore(path=tmp_path / "x.f32", length=33)


def test_ooc_build_nondivisible_and_small(tmp_path):
    """Ragged final chunk + final partial block + capacity > dataset."""
    raw = random_walk(333, 64, seed=17)
    store = storage.SeriesStore.write(tmp_path / "r.f32", raw)
    opened = storage.build_on_disk(store, tmp_path / "r.dsix", capacity=50,
                                   chunk=128)
    idx_mem = core.build(jnp.asarray(raw), capacity=50)
    idx_ooc = storage.load_index(tmp_path / "r.dsix")
    for f in ("raw", "ids", "elo", "ehi"):
        assert np.array_equal(np.asarray(getattr(idx_mem, f)),
                              np.asarray(getattr(idx_ooc, f))), f
    qs = jnp.asarray(raw[:4])
    res = storage.ooc_search(opened, qs, k=3)
    want = search_scan(jnp.asarray(raw), qs, k=3)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
