"""Exact k-NN: every search path against a brute-force ``lax.top_k`` oracle.

The oracle computes the full (Q, N) distance matrix in id order, so
``lax.top_k`` breaks distance ties toward the smaller id — the same
deterministic order the Frontier's (dist, id)-lexicographic sort produces.
Covers k in {1, 5, 32}, k > n_real padding, duplicate-distance ties, and
the distributed all-gather merge; the hypothesis property test checks that
``frontier.threshold()`` pruning never dismisses a true k-NN member.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

import repro.core as core
from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.paris import search_paris
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.kernels import ops
from conftest import run_subprocess

RNG = np.random.default_rng(11)


def walks(n, length, seed):
    r = np.random.default_rng(seed)
    return np.cumsum(r.standard_normal((n, length)), axis=1).astype(np.float32)


def oracle_topk(raw, qs, k):
    """(dist (Q,K), ids (Q,K)) via the full distance matrix + lax.top_k."""
    d = ops.batch_l2(isax.znorm(qs), isax.znorm(raw))         # (Q, N) id order
    neg, ids = jax.lax.top_k(-d, k)
    return np.sqrt(np.maximum(-np.asarray(neg), 0.0)), np.asarray(ids)


PATHS = {
    "messi": lambda idx, raw, qs, k: core.search(idx, qs, k=k),
    "block_major": lambda idx, raw, qs, k: search_block_major(idx, qs, k=k),
    "paris": lambda idx, raw, qs, k: search_paris(idx, qs, k=k, chunk=256),
    "ucr": lambda idx, raw, qs, k: search_scan(raw, qs, k=k),
}


@pytest.mark.parametrize("k", [1, 5, 32])
@pytest.mark.parametrize("path", sorted(PATHS))
def test_topk_matches_oracle(path, k):
    raw = jnp.asarray(walks(768, 128, seed=21))
    qs = jnp.asarray(walks(6, 128, seed=22))
    idx = core.build(raw, capacity=64)
    got = PATHS[path](idx, raw, qs, k)
    want_d, want_i = oracle_topk(raw, qs, k)
    assert got.idx.shape == (6, k)
    assert np.array_equal(np.asarray(got.idx), want_i), path
    np.testing.assert_allclose(np.asarray(got.dist), want_d,
                               rtol=1e-3, atol=5e-3)


@pytest.mark.parametrize("path", sorted(PATHS))
def test_k_larger_than_dataset_pads_with_inf(path):
    """k > n_real: the tail of the frontier stays (INF, -1)."""
    n_real, k = 7, 32
    raw = jnp.asarray(walks(n_real, 64, seed=23))
    qs = jnp.asarray(walks(3, 64, seed=24))
    idx = core.build(raw, capacity=4)
    got = PATHS[path](idx, raw, qs, k)
    want_d, want_i = oracle_topk(raw, qs, n_real)
    gi, gd = np.asarray(got.idx), np.asarray(got.dist)
    assert np.array_equal(gi[:, :n_real], want_i)
    np.testing.assert_allclose(gd[:, :n_real], want_d, rtol=1e-3, atol=5e-3)
    assert (gi[:, n_real:] == -1).all()
    assert (gd[:, n_real:] == np.float32(np.finfo(np.float32).max)).all()


@pytest.mark.parametrize("path", sorted(PATHS))
def test_duplicate_distance_ties_break_by_id(path):
    """Exact duplicate series => tied distances; order must match the
    oracle's smallest-id-first tiebreak on every path."""
    base = walks(32, 64, seed=25)
    raw = jnp.asarray(np.concatenate([base, base, base]))     # ids i, i+32, i+64
    qs = jnp.asarray(base[:4])
    idx = core.build(raw, capacity=8)
    k = 6
    got = PATHS[path](idx, raw, qs, k)
    want_d, want_i = oracle_topk(raw, qs, k)
    assert np.array_equal(np.asarray(got.idx), want_i), path
    # the query's own triplet {q, q+32, q+64} is the tied zero-distance set
    assert np.array_equal(np.asarray(got.idx)[:, :3],
                          np.arange(4)[:, None] + np.array([0, 32, 64]))


def test_frontier_insert_merge_unit():
    """Pure frontier ops: dedup, tie order, padding, merge symmetry."""
    f = frontier_lib.init(1, 3)
    f = f.insert(jnp.asarray([[2.0, 1.0, 5.0]]),
                 jnp.asarray([[7, 9, 4]], jnp.int32))
    assert np.array_equal(np.asarray(f.ids), [[9, 7, 4]])
    # duplicate id keeps one slot at the min distance
    f = f.insert(jnp.asarray([[0.5, 2.0]]), jnp.asarray([[7, 2]], jnp.int32))
    assert np.array_equal(np.asarray(f.ids), [[7, 9, 2]])
    assert np.allclose(np.asarray(f.dists), [[0.5, 1.0, 2.0]])
    # ties break toward the smaller id
    g = frontier_lib.init(1, 3).insert(
        jnp.asarray([[1.0, 1.0, 1.0, 1.0]]),
        jnp.asarray([[8, 3, 11, 5]], jnp.int32))
    assert np.array_equal(np.asarray(g.ids), [[3, 5, 8]])
    # merge == insert of the other frontier's rows; at the tied distance
    # 1.0 the ids {3, 5, 8, 9} compete and the smallest two win
    m = f.merge(g)
    assert np.array_equal(np.asarray(m.ids), [[7, 3, 5]])
    assert np.allclose(np.asarray(m.dists), [[0.5, 1.0, 1.0]])
    # invalid ids never enter; short frontiers stay padded
    h = frontier_lib.init(2, 4).insert(
        jnp.asarray([[1.0, 2.0], [3.0, 4.0]]),
        jnp.asarray([[5, -1], [-1, 6]], jnp.int32))
    assert np.array_equal(np.asarray(h.ids), [[5, -1, -1, -1],
                                              [6, -1, -1, -1]])


def test_distributed_merge_disjoint_topk():
    """Each shard holds a disjoint slice of the true top-k; the round-2
    all-gather + merge must reassemble the exact global answer."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, isax, ucr
from repro.kernels import ops
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(31)
q0 = np.cumsum(rng.standard_normal(128)).astype(np.float32)
# 2048 series range-sharded over 8 shards (256 each); plant the 16 closest
# neighbours two per shard so every shard owns a disjoint piece of the
# true top-16 (background series are independent walks, far away in
# z-norm space).
raw = np.cumsum(rng.standard_normal((2048, 128)).astype(np.float32), axis=1)
for j in range(16):
    shard = j % 8
    raw[shard * 256 + 100 + j // 8] = q0 + 0.03 * (j + 1) * np.sin(
        np.arange(128)).astype(np.float32)
qs = jnp.asarray(np.stack([q0, q0 + 0.05]))
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=64)
k = 16
res = distributed.search_sharded(sidx, qs, mesh, k=k)
want = ucr.search_scan(jnp.asarray(raw), qs, k=k)
d = ops.batch_l2(isax.znorm(qs), isax.znorm(jnp.asarray(raw)))
_, oid = jax.lax.top_k(-d, k)
assert np.array_equal(np.asarray(want.idx), np.asarray(oid))
assert np.array_equal(np.asarray(res.idx), np.asarray(oid))
# near-duplicate distances carry expanded-form L2 noise (see
# kernels/batch_l2.py), so the distance check is absolute-tolerance
assert np.allclose(np.asarray(res.dist), np.asarray(want.dist),
                   rtol=1e-3, atol=5e-3)
# the planted neighbours span multiple shards in the answer
shards_hit = set(int(i) // 256 for i in np.asarray(res.idx[0]))
assert len(shards_hit) >= 4, shards_hit
print("OK")
""")


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 48),
       st.sampled_from([1, 3, 8]), st.sampled_from([32, 64]))
def test_threshold_never_prunes_true_knn(seed, n_series, k, length):
    """Property: pruning against frontier.threshold() keeps every true
    k-NN member, for random shapes, seeds, and k (incl. k > n_series)."""
    r = np.random.default_rng(seed)
    raw = jnp.asarray(np.cumsum(r.standard_normal((n_series, length)),
                                axis=1).astype(np.float32))
    qs = jnp.asarray(np.cumsum(r.standard_normal((2, length)),
                               axis=1).astype(np.float32))
    idx = core.build(raw, capacity=8)
    got = core.search(idx, qs, k=k, blocks_per_iter=2)
    kk = min(k, n_series)
    want_d, want_i = oracle_topk(raw, qs, kk)
    assert np.array_equal(np.asarray(got.idx)[:, :kk], want_i)
    np.testing.assert_allclose(np.asarray(got.dist)[:, :kk], want_d,
                               rtol=1e-3, atol=5e-3)
