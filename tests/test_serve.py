"""Multi-tenant serving (DESIGN.md §9): coalesced submit/drain answers
bit-identically to isolated sessions while fetching fewer blocks; anytime
answers carry a valid two-sided certificate that tightens monotonically
with the deadline; ``refine_to_exact`` upgrades bit-identically without
repeating refined blocks."""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from _hyp import given, settings, st
from repro import serve, storage
from repro.core import engine
from repro.core.ucr import search_scan
from repro.data import random_walk

N, LEN, CAP = 4000, 128, 128


@pytest.fixture(scope="module")
def dataset():
    raw = random_walk(N, LEN, seed=31)
    rng = np.random.default_rng(17)
    picks = rng.choice(N, 12, replace=False)
    qs = jnp.asarray(raw[picks] + 0.05 * rng.standard_normal((12, LEN))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def opened(dataset, tmp_path_factory):
    raw, _ = dataset
    idx = core.build(jnp.asarray(raw), capacity=CAP)
    path = tmp_path_factory.mktemp("serve") / "rw.dsix"
    storage.save_index(idx, path)
    return storage.open_index(path)


def _bitwise(got, want):
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    assert np.array_equal(np.asarray(got.dist), np.asarray(want.dist))


def _isolated(opened, batches):
    """Each batch through its own fresh session; returns results and the
    total disk blocks fetched across all sessions."""
    results, fetched = [], 0
    for qs, kwargs in batches:
        with storage.SearchSession(opened, cache_blocks=64) as sess:
            results.append(sess.search(qs, **kwargs))
            fetched += sess.blocks_fetched
    return results, fetched


# ---------------------------------------------------------------------------
# coalesced serving: exactness and coalescing
# ---------------------------------------------------------------------------

def test_coalesced_drain_bit_identical_to_isolated(dataset, opened):
    """The acceptance property: concurrent tenants (heterogeneous k)
    answered by one coalesced walk match isolated serial sessions
    bitwise, while the shared cache fetches strictly fewer blocks than
    the N sessions do in total."""
    _, qs = dataset
    batches = [(qs[0:4], dict(k=5)), (qs[4:8], dict(k=1)),
               (qs[8:12], dict(k=3))]
    want, isolated_fetches = _isolated(opened, batches)

    with storage.SearchSession(opened, cache_blocks=64) as sess:
        tickets = [sess.submit(q, **kw) for q, kw in batches]
        resolved = sess.drain()
        assert set(resolved) == set(tickets)
        for t, w in zip(tickets, want):
            _bitwise(t.result(), w)
        assert sess.blocks_fetched < isolated_fetches
        assert sess.batches == len(batches)


def test_coalesced_drain_matches_oracle(dataset, opened):
    raw, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        t = sess.submit(qs, k=5)
        sess.drain()
        got = t.result()
    want = search_scan(jnp.asarray(raw), qs, k=5)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


def test_coalesced_mixed_metrics(dataset, opened):
    """ED and DTW tenants share one walk: per-tenant plans keep their
    own metric; answers match each metric's isolated run bitwise."""
    _, qs = dataset
    batches = [(qs[0:3], dict(k=3)),
               (qs[3:6], dict(k=3, metric=engine.DTW(r=4)))]
    want, isolated_fetches = _isolated(opened, batches)
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        tickets = [sess.submit(q, **kw) for q, kw in batches]
        sess.drain()
        for t, w in zip(tickets, want):
            _bitwise(t.result(), w)
        assert sess.blocks_fetched < isolated_fetches


def test_threaded_submitters_one_drain(dataset, opened):
    """Tenant threads submit concurrently and block on their own ticket;
    the first to ask drains for everyone.  Answers equal each thread's
    isolated result."""
    _, qs = dataset
    batches = [(qs[i:i + 3], dict(k=2)) for i in range(0, 12, 3)]
    want, _ = _isolated(opened, batches)
    got = [None] * len(batches)
    errs = []

    with storage.SearchSession(opened, cache_blocks=64) as sess:
        barrier = threading.Barrier(len(batches))

        def tenant(i, q, kw):
            try:
                t = sess.submit(q, **kw)
                barrier.wait()        # everyone admitted before anyone drains
                got[i] = t.result()
            except BaseException as e:   # pragma: no cover - surfaced below
                errs.append(e)

        threads = [threading.Thread(target=tenant, args=(i, q, kw))
                   for i, (q, kw) in enumerate(batches)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errs
    for g, w in zip(got, want):
        _bitwise(g, w)


def test_drain_empty_and_ticket_reuse(dataset, opened):
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        assert sess.drain() == []
        t = sess.submit(qs[:2], k=1)
        sess.drain()
        r1 = t.result()
        assert t.result() is r1          # resolved tickets answer again
        assert sess.drain() == []        # nothing pending anymore


def test_submit_rejects_per_ticket_deadline(dataset, opened):
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        coal = serve.AdmissionCoalescer(sess)
        plan = engine.QueryPlan(metric=engine.ED(), schedule="block_major",
                                k=1, deadline_blocks=3)
        with pytest.raises(ValueError, match="drain"):
            coal.submit(qs[:1], plan)


# ---------------------------------------------------------------------------
# anytime answers and certificates
# ---------------------------------------------------------------------------

def test_anytime_certificate_brackets_truth(dataset, opened):
    """For EVERY query and every deadline, the certified interval must
    bracket the true k-th distance (the subsystem's core guarantee)."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as ref:
        true_kth = np.asarray(ref.search(qs, k=5).dist)[:, -1]
    for deadline in (1, 2, 4, 8, 16):
        with storage.SearchSession(opened, cache_blocks=64) as sess:
            a = sess.search(qs, k=5, deadline_blocks=deadline)
        c = a.certificate
        assert (c.upper >= true_kth - 1e-5 * np.abs(true_kth)).all()
        assert (c.lower <= true_kth + 1e-5 * np.abs(true_kth)).all()
        assert (c.lower <= c.upper).all()
        assert (c.gap >= 0).all()
        # exact flag is self-consistent: zero gap wherever certified
        assert np.allclose(c.gap[c.exact], 0.0)


def test_anytime_tightens_monotonically(dataset, opened):
    """More deadline -> never-worse certificate: upper non-increasing,
    lower non-decreasing, per query (the deadline prefix property)."""
    _, qs = dataset
    prev = None
    for deadline in (1, 2, 4, 8, 16, 32):
        with storage.SearchSession(opened, cache_blocks=64) as sess:
            c = sess.search(qs, k=5, deadline_blocks=deadline).certificate
        if prev is not None:
            assert (c.upper <= prev.upper + 1e-6).all()
            assert (c.lower >= prev.lower - 1e-6).all()
            assert (c.blocks_deferred <= prev.blocks_deferred).all()
        prev = c


def test_refine_to_exact_bit_identical_and_cheaper(dataset, opened):
    """Anytime + continuation == cold exact search (dist, idx, stats),
    with the continuation refining strictly fewer blocks than cold."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as ref:
        want = ref.search(qs, k=5)
        cold_fetches = ref.blocks_fetched
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        a = sess.search(qs, k=5, deadline_blocks=3)
        deferred_before = int(a.certificate.blocks_deferred.max())
        got = a.refine_to_exact()
    _bitwise(got, want)
    for g, w in zip(got.stats, want.stats):
        assert np.array_equal(np.asarray(g), np.asarray(w))
    # the continuation never re-reads what the anytime phase cached
    assert got.io.blocks_fetched < cold_fetches
    assert deferred_before > 0           # the deadline actually cut


def test_refine_to_exact_consumes_once(dataset, opened):
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        a = sess.search(qs[:3], k=2, deadline_blocks=1)
        a.refine_to_exact()
        with pytest.raises(ValueError, match="consumed"):
            a.refine_to_exact()


def test_budgeted_drain_mixes_exact_and_anytime(dataset, opened):
    """A deadline-cut drain resolves finished tenants exact and cut
    tenants anytime; each anytime ticket's continuation still lands on
    its isolated exact answer bitwise."""
    _, qs = dataset
    batches = [(qs[0:4], dict(k=5)), (qs[4:8], dict(k=3))]
    want, _ = _isolated(opened, batches)
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        tickets = [sess.submit(q, **kw) for q, kw in batches]
        sess.drain(deadline_blocks=2)
        for t, (q, kw), w in zip(tickets, batches, want):
            r = t.result()
            if isinstance(r, serve.AnytimeResult):
                c = r.certificate
                true_kth = np.asarray(w.dist)[:, -1]
                assert (c.upper >= true_kth - 1e-5 * np.abs(true_kth)).all()
                assert (c.lower <= true_kth + 1e-5 * np.abs(true_kth)).all()
                _bitwise(r.refine_to_exact(), w)
            else:
                _bitwise(r, w)


def test_session_deadline_validation(dataset, opened):
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        with pytest.raises(ValueError, match="deadline_blocks"):
            sess.search(qs[:2], k=1, deadline_blocks=0)
        with pytest.raises(ValueError, match="fresh batch"):
            prep = sess.approximate_threshold(qs[:2], k=1)
            sess.search(qs[:2], k=1, prepared=prep, deadline_blocks=2)


def test_dtw_wrappers_reject_nonpositive_deadline(dataset):
    from repro.core import dtw as D
    raw, qs = dataset
    idx = core.build(jnp.asarray(raw[:512]), capacity=64)
    with pytest.raises(ValueError, match="deadline_blocks"):
        D.search_dtw(idx, qs[:2], r=4, k=1, deadline_blocks=0)


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------

def test_close_is_idempotent(dataset, opened):
    _, qs = dataset
    sess = storage.SearchSession(opened, cache_blocks=8)
    sess.search(qs[:2], k=1)
    sess.close()
    sess.close()                          # second close is a no-op
    with storage.SearchSession(opened, cache_blocks=8) as cm:
        cm.search(qs[:2], k=1)
        cm.close()                        # explicit close inside the block
    # __exit__ after the explicit close must not raise


# ---------------------------------------------------------------------------
# property test (skips when hypothesis is not installed)
# ---------------------------------------------------------------------------

@given(deadline=st.integers(min_value=1, max_value=24),
       k=st.integers(min_value=1, max_value=8))
@settings(max_examples=12, deadline=None)
def test_certificate_brackets_truth_property(dataset, opened, deadline, k):
    """Certified bound property, over random (deadline, k): the true
    k-th distance always lies in [lower, upper], and upper at full
    budget equals the exact k-th."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=64) as ref:
        true_kth = np.asarray(ref.search(qs, k=k).dist)[:, -1]
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        a = sess.search(qs, k=k, deadline_blocks=deadline)
    c = a.certificate
    assert (c.upper >= true_kth - 1e-5 * np.abs(true_kth)).all()
    assert (c.lower <= true_kth + 1e-5 * np.abs(true_kth)).all()
    # wherever certified exact, the anytime k-th IS the true k-th
    np.testing.assert_allclose(c.upper[c.exact], true_kth[c.exact],
                               rtol=1e-6, atol=1e-6)
