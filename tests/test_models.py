"""Per-arch smoke tests (deliverable f) + layer-level equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, count_params, SHAPES
from repro.models import attention, common, mamba, rwkv
from repro.models import transformer as T
from repro.train import make_train_step, opt_init

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
B, S = 2, 64


def make_batch(cfg, b=B, s=S):
    if cfg.enc_dec:
        return {"frames": jnp.asarray(
                    RNG.standard_normal((b, s, cfg.d_model))
                    .astype(np.float32) * 0.1),
                "dec_tokens": jnp.asarray(
                    RNG.integers(0, cfg.vocab, (b, cfg.decoder_len)),
                    dtype=jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.n_patches
        return {"patches": jnp.asarray(
                    RNG.standard_normal((b, p, cfg.d_model))
                    .astype(np.float32) * 0.1),
                "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s - p)),
                                      dtype=jnp.int32)}
    return {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                  dtype=jnp.int32)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    """REQUIRED per-assignment: reduced config, one forward + one train step
    on CPU, output shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = common.build_params(T.param_specs(cfg), KEY)
    batch = make_batch(cfg)
    logits, _ = T.forward(params, batch, cfg)
    exp_s = cfg.decoder_len if cfg.enc_dec else \
        S - (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))

    step = jax.jit(make_train_step(cfg, base_lr=1e-3, microbatch=1))
    p2, o2, m = step(params, opt_init(cfg.optimizer, params), batch)
    assert np.isfinite(float(m["loss"]))
    assert int(m["skipped"]) == 0
    # params actually changed
    d = float(jnp.max(jnp.abs(p2["embed"] - params["embed"])))
    assert d > 0


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned numbers."""
    cfg = get_config(arch)
    expect = {
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (got, expect)


def test_param_counts_in_expected_range():
    """count_params should land near the advertised sizes."""
    for arch, lo, hi in [("granite-moe-1b-a400m", 0.9e9, 1.6e9),
                         ("h2o-danube-1.8b", 1.4e9, 2.2e9),
                         ("rwkv6-7b", 5e9, 9e9),
                         ("gemma3-27b", 2.2e10, 3.3e10),
                         ("command-r-35b", 2.8e10, 4.2e10),
                         ("nemotron-4-340b", 2.8e11, 4.0e11)]:
        n = count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)


def test_prefill_decode_consistency_dense_moe_ssm():
    """Decode logits == full-forward logits at matching positions (the KV
    cache / recurrent-state path is exactly the training path)."""
    for arch in ("gemma3-27b", "granite-moe-1b-a400m", "rwkv6-7b",
                 "hymba-1.5b"):
        cfg = get_config(arch, smoke=True)
        if cfg.n_experts:
            cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
        params = common.build_params(T.param_specs(cfg), KEY)
        batch = make_batch(cfg)
        full, _ = T.forward(params, batch, cfg)
        n_tok = batch["tokens"].shape[1]
        t0 = n_tok // 2
        cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :t0]
        lg, cache = T.prefill(params, pre, cache, cfg)
        errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, t0 - 1])))]
        dec = jax.jit(lambda p, t, pos, c, _cfg=cfg: T.decode_step(
            p, t, pos, c, _cfg))
        for t in range(t0, n_tok):
            lg, cache = dec(params, batch["tokens"][:, t:t + 1],
                            jnp.asarray(t), cache)
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
        assert max(errs) < 2e-3, (arch, max(errs))


# ---------------------------------------------------------------------------
# attention equivalences
# ---------------------------------------------------------------------------


def naive_attn(q, k, v, *, causal=True, window=0):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bckh->bkgqc", qg, k) * hd ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p, v)
    return out.reshape(b, s, h, hd)


def qkv(s=64, h=4, kvh=2, hd=16, b=2, sk=None):
    sk = s if sk is None else sk
    mk = lambda *sh: jnp.asarray(RNG.standard_normal(sh).astype(np.float32))
    return mk(b, s, h, hd), mk(b, sk, kvh, hd), mk(b, sk, kvh, hd)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_attend_chunked_equals_naive(chunk):
    q, k, v = qkv()
    got = attention.attend(q, k, v, causal=True, chunk=chunk)
    want = naive_attn(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attend_noncausal_cross():
    q, k, v = qkv(s=24, sk=56)
    got = attention.attend(q, k, v, causal=False, chunk=16)
    want = naive_attn(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 24, 48])
def test_attend_swa_equals_masked_full(window):
    q, k, v = qkv()
    got = attention.attend(q, k, v, window=window, chunk=16)
    want = naive_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attend_triangular_equals_full():
    q, k, v = qkv()
    got = attention.attend(q, k, v, causal=True, chunk=16, triangular=True)
    want = naive_attn(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attend_matches_last_row():
    q, k, v = qkv()
    full = naive_attn(q, k, v, causal=True)
    got = attention.decode_attend(q[:, -1:], k, v, jnp.asarray(63), chunk=16)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_swa_ring_cache_decode():
    """Ring-buffer SWA decode == full-cache windowed decode."""
    window = 16
    q, k, v = qkv(s=40)
    # build ring cache from positions 0..39
    ring_k = jnp.zeros((2, window, 2, 16))
    ring_v = jnp.zeros((2, window, 2, 16))
    for t in range(40):
        ring_k, ring_v = attention.cache_update(
            ring_k, ring_v, k[:, t:t + 1], v[:, t:t + 1], jnp.asarray(t),
            window=window)
    got = attention.decode_attend(q[:, -1:], ring_k, ring_v,
                                  jnp.asarray(39), window=window, chunk=16)
    want = naive_attn(q, k, v, causal=True, window=window)[:, -1:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# recurrent layer equivalences (chunked == sequential oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_rwkv_chunked_equals_naive(chunk):
    b, s, h, n = 2, 128, 4, 16
    mk = lambda *sh: jnp.asarray(
        RNG.standard_normal(sh).astype(np.float32) * 0.5)
    r, k, v = mk(b, s, h, n), mk(b, s, h, n), mk(b, s, h, n)
    logw = -jnp.exp(mk(b, s, h, n))
    u = mk(h, n) * 0.2
    s0 = mk(b, h, n, n) * 0.1
    want, s_want = rwkv.rwkv_naive_wkv(r, k, v, logw, u, s0)
    nc = s // min(chunk, s)
    c = s // nc
    resh = lambda a: a.reshape(b, nc, c, h, n).swapaxes(0, 1)

    def step(carry, inp):
        out, s_end = rwkv._chunk_wkv(*inp, u, carry)
        return s_end, out

    s_got, outs = jax.lax.scan(step, s0, (resh(r), resh(k), resh(v),
                                          resh(logw)))
    got = outs.swapaxes(0, 1).reshape(b, s, h, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=1e-3, atol=1e-3)


def test_mamba_chunked_equals_naive():
    class C:
        n_layers = 1
        d_model = 64
        ssm_state = 8
        ssm_conv = 4
    p = jax.tree.map(lambda a: a[0],
                     common.build_params(mamba.param_specs(C, 96), KEY))
    x = jnp.asarray(RNG.standard_normal((2, 96, 64)).astype(np.float32) * .2)
    got, st_c = mamba.mamba_mix(x, p, d_inner=96, chunk=24)
    want, st_n = mamba.mamba_naive(x, p, d_inner=96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c.h), np.asarray(st_n.h),
                               rtol=1e-3, atol=1e-3)


def test_segments_cover_all_layers():
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.family in ("ssm", "audio"):
            continue
        segs = T.segments(cfg)
        assert segs[0].start == 0 and segs[-1].end == cfg.n_layers
        for a, b_ in zip(segs, segs[1:]):
            assert a.end == b_.start
            assert a.kind != b_.kind


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-27b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    assert kinds.count("full") == 10          # every 6th of 62
    assert all(kinds[i] == "full" for i in range(5, 62, 6))


def test_moe_dispatch_everything_kept_with_headroom():
    from repro.models import moe
    x = jnp.asarray(RNG.standard_normal((64, 16)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 8, (64, 2)), dtype=jnp.int32)
    tok, slot, kept = moe._dispatch_indices(ids, 8, cap=64)
    assert bool(kept.all())
    # slots unique among kept
    s = np.asarray(slot)
    assert len(np.unique(s)) == len(s)


def test_moe_capacity_drops_deterministic():
    from repro.models import moe
    # all tokens to expert 0, capacity 8 -> first 8 assignments kept
    ids = jnp.zeros((32, 1), jnp.int32)
    tok, slot, kept = moe._dispatch_indices(ids, 4, cap=8)
    assert int(kept.sum()) == 8
    assert np.array_equal(np.asarray(tok[np.asarray(kept)]), np.arange(8))
