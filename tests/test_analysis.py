"""repro.analysis: the linter suite's contract with the repo.

Three layers:

1. The acceptance gate — the full ``src/`` tree has ZERO findings
   (the same invariant the CI ``lint`` job enforces).
2. Fixture snippets proving each checker actually catches known-bad
   code at the right file:line — lock discipline (including
   ``# caller holds`` delegation), host-sync tracing (jit scope and
   module directive), the kernel-oracle contract, and the
   dispatch-registry contract.
3. The ``REPRO_SANITIZE=1`` runtime wrappers, plus regression tests
   for the two real races the checker's introduction fixed:
   ``IndexFileWriter.append_raw_rows`` off-lock reservation and
   ``SearchSession``'s double-checked coalescer init.
"""
import os
import textwrap
import threading
from pathlib import Path

import numpy as np
import pytest

from conftest import run_subprocess
from repro.analysis import Project, run_analysis
from repro.analysis import contracts, locks, syncs
from repro.analysis.cli import load_project
from repro.analysis import sanitize

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _project(*named):
    return Project.from_sources(
        [(path, textwrap.dedent(src)) for path, src in named])


# -- 1. the acceptance gate -------------------------------------------------

def test_src_tree_has_zero_findings():
    project, parse_errors = load_project([SRC])
    assert not parse_errors
    findings = run_analysis(project)
    assert findings == [], "\n".join(f.text() for f in findings)


# -- 2a. lock-discipline fixtures ------------------------------------------

BAD_LOCK = """\
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0   # guarded by: _lock

    def good(self):
        with self._lock:
            self.n += 1

    def bad(self):
        self.n += 1
"""


def test_lock_checker_flags_offlock_mutation():
    findings = locks.check(_project(("svc/counter.py", BAD_LOCK)))
    assert len(findings) == 1
    f = findings[0]
    assert (f.path, f.code) == ("svc/counter.py", "LOCK001")
    assert f.line == 13          # the `self.n += 1` inside bad()
    assert "Counter.n" in f.message and "_lock" in f.message


def test_lock_checker_flags_offlock_read():
    src = """\
    class C:
        def __init__(self):
            self.items = []   # guarded by: _lock

        def peek(self):
            return len(self.items)
    """
    findings = locks.check(_project(("c.py", src)))
    assert [f.code for f in findings] == ["LOCK001"]
    assert findings[0].line == 6


def test_lock_checker_passes_clean_class():
    src = BAD_LOCK.replace("    def bad(self):\n        self.n += 1\n",
                           "")
    assert locks.check(_project(("svc/counter.py", src))) == []


CALLER_HOLDS = """\
import threading

class Cache:
    def __init__(self):
        self._lock = threading.Lock()
        self._d = {}   # guarded by: _lock

    def _insert(self, k, v):
        # caller holds self._lock
        self._d[k] = v

    def put(self, k, v):
        with self._lock:
            self._insert(k, v)

    def put_racy(self, k, v):
        self._insert(k, v)
"""


def test_caller_holds_delegation():
    findings = locks.check(_project(("cache.py", CALLER_HOLDS)))
    # _insert's own body passes (the annotation grants the lock), the
    # locked call site passes, the off-lock call site is the finding
    assert [(f.code, f.line) for f in findings] == [("LOCK002", 17)]
    assert "_insert" in findings[0].message


def test_unannotated_helper_is_flagged_in_its_body():
    src = CALLER_HOLDS.replace("        # caller holds self._lock\n", "")
    findings = locks.check(_project(("cache.py", src)))
    # without the annotation the helper's own guarded access is the
    # violation (both call sites are then fine to the checker)
    assert [f.code for f in findings] == ["LOCK001"]
    assert "Cache._d" in findings[0].message


def test_nested_function_does_not_inherit_the_lock():
    src = """\
    class C:
        def __init__(self):
            self.n = 0   # guarded by: _lock

        def spawn(self):
            with self._lock:
                def later():
                    self.n += 1     # runs off-thread, lock NOT held
                return later
    """
    findings = locks.check(_project(("c.py", src)))
    assert [(f.code, f.line) for f in findings] == [("LOCK001", 8)]


# -- 2b. host-sync tracer fixtures -----------------------------------------

def test_sync_tracer_flags_asarray_inside_jit():
    src = """\
    import jax
    import numpy as np

    @jax.jit
    def f(x):
        y = x + 1
        return np.asarray(y)
    """
    findings = syncs.check(_project(("m.py", src)))
    assert [(f.code, f.line) for f in findings] == [("SYNC001", 7)]


def test_sync_tracer_flags_float_in_lax_scan_body():
    src = """\
    from jax import lax

    def walk(xs):
        def body(carry, x):
            t = float(carry)
            return carry + x, t
        return lax.scan(body, 0.0, xs)
    """
    findings = syncs.check(_project(("m.py", src)))
    assert [(f.code, f.line) for f in findings] == [("SYNC001", 5)]


def test_sync_annotation_is_the_sanctioned_suppression():
    src = """\
    import jax
    import numpy as np

    @jax.jit
    def f(x):
        return np.asarray(x)   # sync
    """
    assert syncs.check(_project(("m.py", src))) == []


def test_jnp_asarray_is_not_a_sync():
    src = """\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return jnp.asarray(x)
    """
    assert syncs.check(_project(("m.py", src))) == []


def test_module_sync_trace_directive():
    src = """\
    # repro: sync-trace
    import numpy as np

    def host_sched(lb, gids):
        a = np.asarray(lb)
        b = np.asarray(lb)          # sync
        c = np.asarray(gids)        # host ids
        return a, b, c
    """
    findings = syncs.check(_project(("engineish.py", src)))
    assert [(f.code, f.line) for f in findings] == [("SYNC002", 5)]


# -- 2c. contract-checker fixtures -----------------------------------------

REF_OK = """\
def foo_ref(x, *, k):
    return x

def bar_oracle(x):
    return x
"""

KERNEL_FOO = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def foo(x, *, k, tile_n=128, interpret=False):
    return x
"""


def test_oracle_contract_passes_and_strips_tuning_params():
    p = _project(("src/repro/kernels/foo.py", KERNEL_FOO),
                 ("src/repro/kernels/ref.py", REF_OK))
    assert contracts.check_oracles(p) == []


def test_missing_oracle_is_flagged():
    p = _project(("src/repro/kernels/foo.py",
                  KERNEL_FOO.replace("def foo(", "def fresh(")),
                 ("src/repro/kernels/ref.py", REF_OK))
    findings = contracts.check_oracles(p)
    assert [(f.code, f.line) for f in findings] == [("KERN001", 5)]
    assert "fresh_ref" in findings[0].message


def test_oracle_signature_mismatch_is_flagged():
    ref = REF_OK.replace("def foo_ref(x, *, k):", "def foo_ref(x, *, kk):")
    p = _project(("src/repro/kernels/foo.py", KERNEL_FOO),
                 ("src/repro/kernels/ref.py", ref))
    assert [f.code for f in contracts.check_oracles(p)] == ["KERN003"]


def test_oracle_override_comment():
    src = KERNEL_FOO.replace(
        "def foo(x, *, k, tile_n=128, interpret=False):",
        "def bar(x, tile_n=128, interpret=False):   # oracle: bar_oracle")
    p = _project(("src/repro/kernels/bar.py", src),
                 ("src/repro/kernels/ref.py", REF_OK))
    assert contracts.check_oracles(p) == []


OPS = """\
def _use_pallas():
    return False, False

def register_dispatch_cache(fn):
    pass

def batch_l2(q, x):
    use, interp = _use_pallas()
    return q
"""

DISPATCHER = """\
import jax
from repro.kernels import ops

@jax.jit
def search(q, x):
    return helper(q, x)

def helper(q, x):
    return ops.batch_l2(q, x)
"""


def test_unregistered_jitted_dispatcher_is_flagged():
    p = _project(("src/repro/kernels/ops.py", OPS),
                 ("src/repro/core/search.py", DISPATCHER))
    findings = contracts.check_dispatch(p)
    # reached transitively through helper(), two modules away
    assert [(f.code, f.path, f.line) for f in findings] == \
        [("DISP001", "src/repro/core/search.py", 5)]
    assert "register_dispatch_cache" in findings[0].message


def test_registered_dispatcher_passes():
    src = DISPATCHER + "\n\nops.register_dispatch_cache(search)\n"
    p = _project(("src/repro/kernels/ops.py", OPS),
                 ("src/repro/core/search.py", src))
    assert contracts.check_dispatch(p) == []


def test_jitted_function_not_reaching_ops_needs_no_registration():
    src = """\
    import jax

    @jax.jit
    def pure(q):
        return q * 2
    """
    p = _project(("src/repro/kernels/ops.py", OPS),
                 ("src/repro/core/pure.py", src))
    assert contracts.check_dispatch(p) == []


# -- 3. runtime sanitizer ---------------------------------------------------

def test_sanitizer_is_off_by_default():
    assert not sanitize.enabled() or os.environ.get("REPRO_SANITIZE")
    lock = sanitize.create_lock()
    if not sanitize.enabled():
        assert isinstance(lock, type(threading.Lock()))


def test_instrumented_lock_tracks_owner():
    lock = sanitize.InstrumentedLock()
    assert not lock.held_by_me()
    with lock:
        assert lock.held_by_me()
        assert lock.locked()
    assert not lock.held_by_me()


_SANITIZE_CODE = """
import numpy as np
from repro.analysis.sanitize import SanitizeError
from repro.storage.format import IndexFileWriter

wr = IndexFileWriter("/tmp/_san.dsix", n=8, w=4, card=4, capacity=4,
                     n_real=16, n_blocks=4, tmp_path="/tmp/_san.partial")
wr.append_raw_rows(np.zeros((4, 8), np.float32))   # locked path: fine

with wr._lock:
    wr._raw_rows = 0                                # held: fine

try:
    wr._raw_rows = 7                                # off-lock: must raise
except SanitizeError:
    print("CAUGHT")
else:
    print("MISSED")
finally:
    wr.abort()
"""


def test_sanitize_offlock_mutation_raises():
    out = run_subprocess(
        "import os; os.environ['REPRO_SANITIZE'] = '1'\n" + _SANITIZE_CODE,
        devices=1)
    assert "CAUGHT" in out and "MISSED" not in out


def test_sanitize_off_means_no_assertion():
    out = run_subprocess(
        "import os; os.environ.pop('REPRO_SANITIZE', None)\n"
        + _SANITIZE_CODE.replace("except SanitizeError:",
                                 "except AssertionError:"),
        devices=1)
    assert "MISSED" in out     # plain lock, no holder tracking


# -- 3b. regression tests for the races the checker surfaced ---------------

def test_concurrent_append_raw_rows_get_disjoint_spans(tmp_path):
    """Pre-fix, ``append_raw_rows`` read-then-bumped ``_raw_rows`` off
    lock: two appenders could reserve the same start row and one
    span's rows would be lost.  Reserve-under-lock makes concurrent
    appends land each row exactly once."""
    from repro.storage import format as format_lib
    n, cap, n_blocks = 8, 4, 4
    total = cap * n_blocks
    path = tmp_path / "c.dsix"
    wr = format_lib.IndexFileWriter(path, n=n, w=4, card=4, capacity=cap,
                                    n_real=total, n_blocks=n_blocks)
    start = threading.Barrier(8)

    def appender(i):
        rows = np.full((2, n), 0.0, np.float32)
        rows[0, :] = 2 * i
        rows[1, :] = 2 * i + 1
        start.wait()
        wr.append_raw_rows(rows)

    threads = [threading.Thread(target=appender, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wr.close()

    idx = format_lib.open_index(path)
    got = sorted(float(idx.host_raw.fetch(b)[r, 0])
                 for b in range(n_blocks) for r in range(cap))
    assert got == [float(v) for v in range(total)]


def test_concurrent_submit_builds_one_coalescer(rng):
    """Pre-fix, ``submit`` read ``_coalescer`` outside the lock
    (double-checked init).  All concurrent submitters must share ONE
    coalescer and every ticket must resolve exactly."""
    import jax.numpy as jnp
    import repro.core as core
    from repro import storage
    raw = rng.standard_normal((512, 64)).astype(np.float32)
    idx = core.build(jnp.asarray(raw), capacity=64)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "rw.dsix"
        storage.save_index(idx, path)
        opened = storage.open_index(path)
        qs = jnp.asarray(raw[:4])
        with storage.SearchSession(opened, cache_blocks=16) as sess:
            want = sess.search(qs, k=3)
            start = threading.Barrier(6)
            tickets = [None] * 6

            def submitter(i):
                start.wait()
                tickets[i] = sess.submit(qs, k=3)

            threads = [threading.Thread(target=submitter, args=(i,))
                       for i in range(6)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            coalescers = {t._coalescer for t in tickets}
            assert len(coalescers) == 1
            sess.drain()
            for t in tickets:
                got = t.result(timeout=60)
                assert np.array_equal(np.asarray(got.idx),
                                      np.asarray(want.idx))
                assert np.array_equal(np.asarray(got.dist),
                                      np.asarray(want.dist))
