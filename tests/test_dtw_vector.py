"""DTW extension (§V of the paper) and generic-vector (embedding) search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import dtw as D
from repro.core import isax, vector
from repro.data import random_walk

RNG = np.random.default_rng(3)


def naive_dtw(a, b, r):
    n = len(a)
    INF = np.inf
    dp = np.full((n + 1, n + 1), INF)
    dp[0, 0] = 0
    for i in range(1, n + 1):
        for j in range(max(1, i - r), min(n, i + r) + 1):
            c = (a[i - 1] - b[j - 1]) ** 2
            dp[i, j] = c + min(dp[i - 1, j], dp[i, j - 1], dp[i - 1, j - 1])
    return dp[n, n]


@pytest.mark.parametrize("r", [2, 5, 10])
def test_dtw_band_matches_naive(r):
    a = RNG.standard_normal(32).astype(np.float32)
    b = RNG.standard_normal(32).astype(np.float32)
    got = float(D.dtw_band(jnp.asarray(a), jnp.asarray(b), r))
    want = naive_dtw(a, b, r)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dtw_zero_distance_to_self():
    a = jnp.asarray(RNG.standard_normal(64).astype(np.float32))
    assert float(D.dtw_band(a, a, 5)) < 1e-6


def test_lb_keogh_lower_bounds_dtw():
    r = 5
    q = RNG.standard_normal((4, 48)).astype(np.float32)
    x = RNG.standard_normal((32, 48)).astype(np.float32)
    env = D.query_envelope(jnp.asarray(q), r)
    lb = np.asarray(D.lb_keogh(env, jnp.asarray(x)))
    for i in range(4):
        for j in range(32):
            d = naive_dtw(q[i], x[j], r)
            assert lb[i, j] <= d + 1e-3, (i, j, lb[i, j], d)


def test_search_dtw_exact_vs_bruteforce():
    raw = jnp.asarray(random_walk(256, 64, seed=9))
    qs = jnp.asarray(random_walk(8, 64, seed=10) * 0.9)
    idx = core.build(raw, capacity=32)
    got = D.search_dtw(idx, qs, r=6)
    qz = isax.znorm(qs)
    xz = isax.znorm(raw)
    bf = D.dtw_band(qz[:, None, :], xz[None], 6)
    np.testing.assert_allclose(np.asarray(got.dist[:, 0]),
                               np.sqrt(np.min(np.asarray(bf), axis=1)),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.asarray(got.idx[:, 0]),
                          np.argmin(np.asarray(bf), axis=1))


def test_search_dtw_topk_vs_bruteforce():
    """k-NN under DTW: same frontier machinery, DTW distances."""
    import jax
    raw = jnp.asarray(random_walk(256, 64, seed=9))
    qs = jnp.asarray(random_walk(4, 64, seed=10) * 0.9)
    idx = core.build(raw, capacity=32)
    k = 5
    got = D.search_dtw(idx, qs, r=6, k=k)
    bf = D.dtw_band(isax.znorm(qs)[:, None, :], isax.znorm(raw)[None], 6)
    _, want = jax.lax.top_k(-bf, k)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want))


@pytest.mark.parametrize("seeded", [False, True])
def test_search_dtw_flat_exact_vs_bruteforce(seeded):
    """DTW x flat (the last open matrix cell): the ParIS scan under the
    DTW metric returns the exact k-NN, with and without stage-A seeding
    from the block view."""
    import jax
    raw = jnp.asarray(random_walk(256, 64, seed=9))
    qs = jnp.asarray(random_walk(4, 64, seed=10) * 0.9)
    fidx = core.build_flat(raw)
    bidx = core.build(raw, capacity=32) if seeded else None
    k = 5
    got = D.search_dtw_flat(fidx, qs, r=6, k=k, block_index=bidx, chunk=64)
    bf = np.asarray(D.dtw_band(isax.znorm(qs)[:, None, :],
                               isax.znorm(raw)[None], 6))
    _, want = jax.lax.top_k(-jnp.asarray(bf), k)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got.dist),
                               np.sort(np.sqrt(bf), axis=1)[:, :k],
                               rtol=1e-4, atol=1e-4)


def test_vector_index_cosine_nn():
    """§V application: exact cosine NN over unit-normalized embeddings."""
    embs = RNG.standard_normal((2048, 64)).astype(np.float32)
    vidx = vector.build_vector_index(jnp.asarray(embs), capacity=128)
    q = embs[:8] + 0.01 * RNG.standard_normal((8, 64)).astype(np.float32)
    res = vector.search_vectors(vidx, jnp.asarray(q))
    # brute-force cosine
    en = embs / np.linalg.norm(embs, axis=1, keepdims=True)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    want = np.argmax(qn @ en.T, axis=1)
    assert np.array_equal(np.asarray(res.idx[:, 0]), want)


def test_vector_index_cosine_topk():
    """k-NN over embeddings: ids AND cosine scores match brute force."""
    import jax
    embs = RNG.standard_normal((1024, 64)).astype(np.float32)
    vidx = vector.build_vector_index(jnp.asarray(embs), capacity=128)
    q = embs[:4] + 0.01 * RNG.standard_normal((4, 64)).astype(np.float32)
    k = 8
    res = vector.search_vectors(vidx, jnp.asarray(q), k=k)
    en = embs / np.linalg.norm(embs, axis=1, keepdims=True)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    cos = qn @ en.T
    want_cos, want_ids = jax.lax.top_k(jnp.asarray(cos), k)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want_ids))
    np.testing.assert_allclose(
        np.asarray(vector.cosine_scores(res, dim=64)),
        np.asarray(want_cos), rtol=1e-4, atol=1e-4)


def test_vector_index_euclidean_mode():
    embs = RNG.standard_normal((512, 32)).astype(np.float32) * 3
    vidx = vector.build_vector_index(jnp.asarray(embs), capacity=64,
                                     unit_norm=False)
    res = vector.search_vectors(vidx, jnp.asarray(embs[:4]),
                                unit_norm=False)
    assert np.array_equal(np.asarray(res.idx[:, 0]), np.arange(4))
    assert np.allclose(np.asarray(res.dist), 0, atol=1e-2)
