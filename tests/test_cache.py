"""Block cache + SearchSession (DESIGN.md §5): warm-cache parity with the
cold run, disk-read accounting (each block at most once per batch), LRU
capacity bounds, and hit-rate monotonicity in cache size."""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from repro import storage
from repro.core.ucr import search_scan
from repro.data import random_walk

DIST_TOL = dict(rtol=1e-5, atol=2e-2)


@pytest.fixture(scope="module")
def dataset():
    raw = random_walk(4000, 128, seed=23)
    rng = np.random.default_rng(11)
    qs = jnp.asarray(raw[rng.choice(4000, 6, replace=False)]
                     + 0.05 * rng.standard_normal((6, 128))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def opened(dataset, tmp_path_factory):
    raw, _ = dataset
    idx = core.build(jnp.asarray(raw), capacity=128)
    path = tmp_path_factory.mktemp("cache") / "rw.dsix"
    storage.save_index(idx, path)
    return storage.open_index(path)


def test_session_matches_one_shot_and_oracle(dataset, opened):
    raw, qs = dataset
    one_shot = storage.ooc_search(opened, qs, k=5)
    with storage.SearchSession(opened, cache_blocks=8) as sess:
        got = sess.search(qs, k=5)
    assert np.array_equal(np.asarray(got.idx), np.asarray(one_shot.idx))
    assert np.array_equal(np.asarray(got.dist), np.asarray(one_shot.dist))
    want = search_scan(jnp.asarray(raw), qs, k=5)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               **DIST_TOL)


def test_warm_repeat_bit_identical_and_zero_disk_bytes(dataset, opened):
    """The acceptance property: a repeated batch through a session large
    enough to hold every surviving block answers bit-identically while
    reading 0 disk bytes."""
    _, qs = dataset
    with storage.SearchSession(opened,
                               cache_blocks=opened.n_blocks) as sess:
        cold = sess.search(qs, k=5)
        warm = sess.search(qs, k=5)
    assert np.array_equal(np.asarray(cold.idx), np.asarray(warm.idx))
    assert np.array_equal(np.asarray(cold.dist), np.asarray(warm.dist))
    assert cold.io.blocks_fetched > 0 and cold.io.cache_hits == 0
    assert warm.io.bytes_read == 0 and warm.io.blocks_fetched == 0
    # the warm walk touches the same surviving blocks, now all resident
    assert warm.io.cache_hits == cold.io.blocks_fetched
    assert sess.hit_rate == pytest.approx(0.5)


def test_blocks_fetched_each_block_at_most_once_per_batch(dataset, opened):
    """Regression for the slot-keyed prefetch bugs: with fetching unified
    behind the id-keyed cache, one batch reads any given block from disk
    at most once, and ``blocks_fetched`` counts exactly those reads."""
    _, qs = dataset
    calls: list[int] = []
    orig = opened.host_raw.fetch
    opened.host_raw.fetch = lambda b: (calls.append(int(b)), orig(b))[1]
    try:
        res = storage.ooc_search(opened, qs, k=5)
    finally:
        del opened.host_raw.fetch          # restore the class method
    counts = np.bincount(calls, minlength=opened.n_blocks)
    assert counts.max() <= 1, f"block(s) read twice in one batch: " \
        f"{np.nonzero(counts > 1)[0].tolist()}"
    assert res.io.blocks_fetched == len(calls)
    assert res.io.bytes_read == len(calls) * opened.host_raw.block_nbytes


def test_small_cache_evicts_but_stays_exact(dataset, opened):
    raw, qs = dataset
    want = search_scan(jnp.asarray(raw), qs, k=3)
    with storage.SearchSession(opened, cache_blocks=2) as sess:
        got = sess.search(qs, k=3)
        assert len(sess.cache) <= 2
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


def test_hit_rate_monotone_in_cache_capacity(dataset, opened):
    """LRU is a stack algorithm and the block-touch trace is cache-
    independent, so total hits over a fixed batch sequence can only grow
    with capacity — and the answers never change."""
    raw, _ = dataset
    rng = np.random.default_rng(77)
    batches = [jnp.asarray(raw[rng.choice(4000, 4, replace=False)]
                           + 0.05 * rng.standard_normal((4, 128))
                           .astype(np.float32))
               for _ in range(4)]
    hits, results = [], []
    for cap in (2, 4, 8, 16, opened.n_blocks):
        with storage.SearchSession(opened, cache_blocks=cap) as sess:
            res = [sess.search(b, k=3) for b in batches]
            hits.append(sess.cache_hits)
        results.append(res)
    assert hits == sorted(hits), f"hits not monotone in capacity: {hits}"
    assert hits[-1] > hits[0]              # repetition across batches exists
    for res in results[1:]:
        for a, b in zip(results[0], res):
            assert np.array_equal(np.asarray(a.idx), np.asarray(b.idx))
            assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist))


def test_cache_capacity_floor():
    class _Host:                           # never touched before the raise
        pass
    with pytest.raises(ValueError, match=">= 2"):
        storage.BlockCache(_Host(), 1)


def test_session_requires_host_raw(dataset):
    raw, qs = dataset
    idx = core.build(jnp.asarray(raw), capacity=128)
    with pytest.raises(ValueError, match="host_raw"):
        storage.SearchSession(idx)


def test_bytes_scan_derives_itemsize_from_raw_dtype(dataset, opened):
    _, qs = dataset
    res = storage.ooc_search(opened, qs, k=1)
    item = opened.host_raw.dtype.itemsize
    assert res.io.bytes_scan == opened.n_real * opened.n * item


def test_failed_read_does_not_poison_the_cache(dataset, opened):
    """A transient I/O error must not leave a stale in-flight entry that
    masquerades as a cached block (and re-raises forever): the failed
    read removes itself and the next request retries."""
    raw, qs = dataset

    def broken(b):
        raise OSError("transient read failure")

    with storage.SearchSession(opened, cache_blocks=8) as sess:
        opened.host_raw.fetch = broken
        try:
            with pytest.raises(OSError, match="transient"):
                sess.search(qs, k=3)       # every disk read fails loudly
        finally:
            del opened.host_raw.fetch      # restore the class method
        sess.cache.drain()                 # let failed speculations settle
        assert not sess.cache._inflight    # nothing stale left behind
        got = sess.search(qs, k=3)         # "disk" healed: retry succeeds
    want = search_scan(jnp.asarray(raw), qs, k=3)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


def test_no_inflight_reads_survive_a_batch(dataset, opened):
    """A speculated-then-pruned read is drained into the cache (and this
    batch's bill) before the result is returned — nothing is left in
    flight to double-charge or leak."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=8) as sess:
        sess.search(qs, k=5)
        assert not sess.cache._inflight
        assert len(sess.cache) <= 8


# -- reader pool + bounded speculation (the depth-D pipeline's cache) ----


def test_multi_reader_drain_lands_every_inflight_read(opened):
    """drain() must wait out ALL outstanding reads from the pool, not
    just one: after a burst of prefetches every block is resident and
    nothing is left in flight."""
    cache = storage.BlockCache(opened.host_raw, opened.n_blocks,
                               readers=3, max_inflight=8)
    try:
        for b in range(8):
            cache.prefetch(b)
        cache.drain()
        assert not cache._inflight
        assert len(cache) == 8                 # every read published
        assert cache.disk_blocks == 8
    finally:
        cache.close()


def test_prefetch_declines_at_max_inflight_but_get_never_does(opened):
    """Speculation is bounded: once max_inflight reads are outstanding,
    further prefetches are silent no-ops — while a demand get always
    submits (and counts the stall)."""
    import threading as th
    gate = th.Event()
    orig = opened.host_raw.fetch
    opened.host_raw.fetch = lambda b: (gate.wait(10), orig(b))[1]
    cache = storage.BlockCache(opened.host_raw, opened.n_blocks,
                               readers=2, max_inflight=2)
    try:
        cache.prefetch(0)
        cache.prefetch(1)
        cache.prefetch(2)                      # at the bound: declined
        assert 2 in cache._inflight or 2 not in cache
        assert len(cache._inflight) == 2
        gate.set()
        cache.drain()
        assert len(cache) == 2                 # block 2 was never read
        assert cache.demand_misses == 0
        got = cache.get(2)                     # demand is never declined
        assert got.shape == (opened.capacity, opened.n)
        assert cache.demand_misses == 1
    finally:
        del opened.host_raw.fetch
        cache.close()


def test_close_idempotent_under_inflight_reads(opened):
    """Regression: close() with several reads still in flight (from the
    multi-thread pool) must wait them out, shut down, and stay correct
    when called again — no deadlock, no resurrection of LRU entries, no
    lost disk accounting."""
    import threading as th
    gate = th.Event()
    orig = opened.host_raw.fetch
    opened.host_raw.fetch = lambda b: (gate.wait(10), orig(b))[1]
    cache = storage.BlockCache(opened.host_raw, 8, readers=3,
                               max_inflight=4)
    try:
        for b in range(4):
            cache.prefetch(b)
        assert len(cache._inflight) == 4       # all four genuinely pending
        closer = th.Thread(target=cache.close)
        closer.start()
        gate.set()                             # release the readers
        closer.join(timeout=10)
        assert not closer.is_alive(), "close() deadlocked on in-flight reads"
    finally:
        del opened.host_raw.fetch
    cache.close()                              # idempotent second close
    assert len(cache) == 0                     # LRU dropped, stays dropped
    assert not cache._inflight
    assert cache.disk_blocks == 4              # counters settled first
    cache.prefetch(5)                          # late speculation: no-op
    assert not cache._inflight
    with pytest.raises(ValueError, match="closed"):
        cache.get(5)                           # demand after close is a bug
