"""Staged build pipeline: sharded byte-identity, k-way merge order
property, SIGKILL crash-resume (manifest), truncation rejection, and
format v1 back-compat (DESIGN.md §5)."""
import hashlib
import json
import os
import signal
import struct
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from repro import storage
from repro.data import random_walk
from repro.storage.pipeline import (BuildInterrupted, build_run,
                                    merge_order, run_pipeline)

CAP, CHUNK, LEN = 32, 128, 64


def _sha(path) -> str:
    return hashlib.sha256(Path(path).read_bytes()).hexdigest()


def _golden(tmp_path, raw) -> Path:
    """save_index(core.build(...)) — the byte-identity reference."""
    p = tmp_path / "golden.dsix"
    storage.save_index(core.build(jnp.asarray(raw), capacity=CAP), p)
    return p


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    raw = random_walk(600, LEN, seed=23)       # 600 % 32 != 0: pad unit too
    td = tmp_path_factory.mktemp("pipe")
    store = storage.SeriesStore.write(td / "series.f32", raw)
    return raw, store, td


def test_sharded_build_byte_identical_and_counted(dataset, tmp_path):
    """Acceptance: >=2 workers, several shards -> byte-identical file."""
    raw, store, _ = dataset
    out = tmp_path / "sharded.dsix"
    path, rep = run_pipeline(store, out, capacity=CAP, chunk=CHUNK,
                             workers=2, shards=3)
    assert _sha(path) == _sha(_golden(tmp_path, raw))
    assert not rep.resumed
    assert rep.stages["runs"].built == 3 and rep.stages["runs"].reused == 0
    assert rep.stages["merge"].built == 1
    assert rep.stages["publish"].built == 1
    assert not (tmp_path / "sharded.dsix.build").exists()   # work dir gone


def test_shard_count_does_not_change_bytes(dataset, tmp_path):
    raw, store, _ = dataset
    ref = _sha(_golden(tmp_path, raw))
    for shards in (1, 2, 5):
        out = tmp_path / f"s{shards}.dsix"
        run_pipeline(store, out, capacity=CAP, chunk=CHUNK, shards=shards)
        assert _sha(out) == ref, f"shards={shards}"


def test_merge_random_shard_splits_match_global_lexsort(dataset, tmp_path):
    """Property: ANY shard split k-way merges to the single-pass lexsort
    order (stable ascending, ties by source id)."""
    raw, store, _ = dataset
    n = len(store)
    # the single-sort oracle: the in-memory builder's own global ordering
    from repro.core import isax
    from repro.kernels import ops
    _, sax = ops.summarize(jnp.asarray(raw), w=isax.W, card=isax.CARD)
    want = np.asarray(isax.sort_order(sax, isax.W)).astype(np.int64)

    rng = np.random.default_rng(0)
    for trial in range(4):
        n_cuts = int(rng.integers(1, 6))
        cuts = np.sort(rng.choice(np.arange(1, n), n_cuts, replace=False))
        bounds = [0, *cuts.tolist(), n]
        paths = []
        for i in range(len(bounds) - 1):
            p = tmp_path / f"t{trial}-run{i}.dsix"
            build_run(store, p, row_start=bounds[i], row_stop=bounds[i + 1],
                      w=isax.W, card=isax.CARD, chunk=CHUNK, normalize=True)
            paths.append(p)
        got = merge_order(paths)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"splits {bounds}")


def _spawn_build(store, out, *, kill_after: str, shards: int = 3) -> int:
    """Run a pipeline build in a subprocess with the kill-switch armed;
    -> returncode (expected -SIGKILL)."""
    code = (
        "import sys\n"
        "from repro.storage import SeriesStore\n"
        "from repro.storage.pipeline import run_pipeline\n"
        "store = SeriesStore(path=sys.argv[1], length=int(sys.argv[2]))\n"
        "run_pipeline(store, sys.argv[3], capacity=int(sys.argv[4]),\n"
        "             chunk=int(sys.argv[5]), shards=int(sys.argv[6]))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["REPRO_BUILD_KILL_AFTER"] = kill_after
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-c", code, str(store.path), str(store.length),
         str(out), str(CAP), str(CHUNK), str(shards)],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode != 0, f"kill switch did not fire:\n{r.stdout}"
    return r.returncode


@pytest.mark.parametrize("kill_after,expect", [
    # SIGKILL after the 1st completed pass-1 run: resume must reuse
    # exactly that run and rebuild the other 2 + everything downstream
    ("runs:1", dict(runs=(2, 1), permute_reused=0)),
    # SIGKILL after the 1st completed pass-2 permute unit: every pass-1
    # run, the merge, and the summaries must be reused, plus that unit
    ("permute:1", dict(runs=(0, 3), permute_reused=1)),
])
def test_sigkill_resume_byte_identical(dataset, tmp_path, kill_after, expect):
    raw, store, _ = dataset
    out = tmp_path / "killed.dsix"
    rc = _spawn_build(store, out, kill_after=kill_after)
    assert rc == -signal.SIGKILL
    assert not out.exists()                      # never a partial publish
    work = out.with_name(out.name + ".build")
    assert (work / "manifest.json").exists()

    messages = []
    path, rep = run_pipeline(store, out, capacity=CAP, chunk=CHUNK,
                             shards=3, progress=messages.append)
    assert rep.resumed
    assert any("resuming from manifest" in m for m in messages)
    built, reused = expect["runs"]
    assert (rep.stages["runs"].built, rep.stages["runs"].reused) \
        == (built, reused)
    assert rep.stages["permute"].reused == expect["permute_reused"]
    if kill_after.startswith("permute"):
        assert rep.stages["merge"].reused == 1
        assert rep.stages["summaries"].reused == 1
    assert _sha(path) == _sha(_golden(tmp_path, raw))


def test_inprocess_interrupt_resume_counters(dataset, tmp_path):
    """The bench's injected-kill shape: a fault hook raises mid-permute;
    the partial survives and the resume redoes only what was pending."""
    raw, store, _ = dataset
    out = tmp_path / "fault.dsix"

    def fault(stage, done):
        if stage == "permute" and done >= 2:
            raise BuildInterrupted(f"{stage}:{done}")

    with pytest.raises(BuildInterrupted):
        run_pipeline(store, out, capacity=CAP, chunk=CHUNK, shards=2,
                     fault=fault)
    n_units = -(-len(store) // CHUNK) + 1        # + the pad unit
    path, rep = run_pipeline(store, out, capacity=CAP, chunk=CHUNK, shards=2)
    assert rep.resumed
    assert rep.stages["permute"].reused == 2
    assert rep.stages["permute"].built == n_units - 2
    assert _sha(path) == _sha(_golden(tmp_path, raw))


def test_completed_build_rerun_is_a_verified_noop(dataset, tmp_path):
    raw, store, _ = dataset
    out = tmp_path / "noop.dsix"
    run_pipeline(store, out, capacity=CAP, chunk=CHUNK, keep_work=True)
    before = _sha(out)
    path, rep = run_pipeline(store, out, capacity=CAP, chunk=CHUNK,
                             keep_work=True)
    assert rep.stages["publish"].reused == 1     # verified, nothing redone
    assert rep.stages["runs"].built == 0 and rep.stages["permute"].built == 0
    assert _sha(path) == before


def test_manifest_param_mismatch_starts_fresh(dataset, tmp_path):
    raw, store, _ = dataset
    out = tmp_path / "fresh.dsix"

    def fault(stage, done):
        if stage == "merge":
            raise BuildInterrupted(stage)

    with pytest.raises(BuildInterrupted):
        run_pipeline(store, out, capacity=CAP, chunk=CHUNK, shards=2,
                     fault=fault)
    # different capacity -> different output bytes: the stale manifest
    # must NOT be resumed
    messages = []
    path, rep = run_pipeline(store, out, capacity=CAP * 2, chunk=CHUNK,
                             shards=2, progress=messages.append)
    assert not rep.resumed
    assert any("starting fresh" in m for m in messages)
    assert rep.stages["runs"].built == 2 and rep.stages["runs"].reused == 0
    golden = tmp_path / "g2.dsix"
    storage.save_index(core.build(jnp.asarray(raw), capacity=CAP * 2), golden)
    assert _sha(path) == _sha(golden)


# ---------------------------------------------------------------------------
# satellite: truncation rejection + format v1 back-compat
# ---------------------------------------------------------------------------

def test_truncated_index_rejected_loudly(dataset, tmp_path):
    raw, store, _ = dataset
    good = _golden(tmp_path, raw)
    bad = tmp_path / "trunc.dsix"
    bad.write_bytes(good.read_bytes()[:-4097])   # torn copy: tail missing
    with pytest.raises(ValueError, match="truncated/partial"):
        storage.load_index(bad)
    with pytest.raises(ValueError, match="truncated/partial"):
        storage.open_index(bad)
    # header-level truncation fails loudly too, not with a JSON error
    bad.write_bytes(good.read_bytes()[:40])
    with pytest.raises(ValueError, match="truncated header"):
        storage.read_meta(bad)


def test_run_file_rejected_as_index(dataset, tmp_path):
    from repro.core import isax
    _, store, _ = dataset
    p = tmp_path / "arun.dsix"
    build_run(store, p, row_start=0, row_stop=100, w=isax.W, card=isax.CARD,
              chunk=CHUNK, normalize=True)
    with pytest.raises(ValueError, match="not an index"):
        storage.load_index(p)
    with pytest.raises(ValueError, match="not an index"):
        storage.open_index(p)


def _downgrade_to_v1(src: Path, dst: Path) -> None:
    """Rewrite a v2 index file as its exact v1 (pre-pipeline) bytes.

    v2 only added the meta 'kind' field (first key); stripping it restores
    the v1 meta JSON key-for-key, and the section layout is unchanged, so
    the data region is copied verbatim — this reproduces what the seed
    writer emitted for the same index.
    """
    blob_all = src.read_bytes()
    meta_len, data_start = struct.unpack("<QQ", blob_all[8:24])
    meta = json.loads(blob_all[24:24 + meta_len].decode())
    assert meta.pop("kind") == "index"
    blob = json.dumps(meta).encode()
    new_start = -(-(24 + len(blob)) // 4096) * 4096
    out = bytearray()
    out += b"DSIX" + struct.pack("<I", 1)
    out += struct.pack("<QQ", len(blob), new_start)
    out += blob
    out += b"\0" * (new_start - len(out))
    out += blob_all[data_start:]
    dst.write_bytes(bytes(out))


def test_v1_index_files_still_load_bit_exact(dataset, tmp_path):
    """Back-compat: the previous on-disk generation (format v1, no 'kind')
    loads bit-exactly through the v2 reader — the format-versioning story
    earning its keep across the bump."""
    raw, store, _ = dataset
    v2 = _golden(tmp_path, raw)
    v1 = tmp_path / "legacy.dsix"
    _downgrade_to_v1(v2, v1)

    meta = storage.read_meta(v1)
    assert meta["version"] == 1 and meta["kind"] == "index"

    a, b = storage.load_index(v1), storage.load_index(v2)
    for f in ("raw", "slo", "shi", "elo", "ehi", "ids"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f
    for f in ("n", "w", "card", "capacity", "n_real"):
        assert getattr(a, f) == getattr(b, f), f

    # and the out-of-core open streams the same blocks
    opened = storage.open_index(v1)
    qs = jnp.asarray(raw[:3])
    res = storage.ooc_search(opened, qs, k=3)
    want = storage.ooc_search(storage.open_index(v2), qs, k=3)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
    assert np.array_equal(np.asarray(res.dist), np.asarray(want.dist))
