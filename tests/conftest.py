"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the single real
device; the distributed suite spawns subprocesses that set their own
device-count flag (see tests/test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N fake XLA devices."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
