"""Index construction: one-shot == streaming (ParIS+ path), padding, ids."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import isax
from repro.core.index import flat_view
from repro.data import ChunkedLoader
from repro.data.loader import IncrementalBuilder, build_streaming
from repro.data import random_walk

from tests._hyp import given, settings, st


def test_streaming_equals_oneshot():
    raw = random_walk(1000, 128, seed=11)
    a = core.build(jnp.asarray(raw), capacity=64)
    b = build_streaming(raw, chunk=256, capacity=64)
    for f in ("raw", "slo", "shi", "elo", "ehi", "ids"):
        np.testing.assert_allclose(np.asarray(getattr(a, f)),
                                   np.asarray(getattr(b, f)),
                                   rtol=1e-5, atol=1e-5, err_msg=f)


def test_loader_chunking_covers_everything():
    raw = random_walk(700, 64, seed=2)
    loader = ChunkedLoader(raw, chunk=256)
    seen = sum(c.shape[0] for c in loader)
    assert seen == 700
    assert len(loader) == 3


def test_loader_file_source(tmp_path):
    """str | Path sources are np.memmap'd (headerless f32 rows)."""
    raw = random_walk(300, 32, seed=21)
    path = tmp_path / "series.f32"
    path.write_bytes(raw.tobytes())
    for src in (str(path), path):
        loader = ChunkedLoader(src, chunk=128, length=32)
        assert loader.n_series == 300 and len(loader) == 3
        got = np.concatenate([np.asarray(c) for c in loader])
        np.testing.assert_array_equal(got, raw)
    with pytest.raises(ValueError, match="length"):
        ChunkedLoader(str(path), chunk=128)
    with pytest.raises(ValueError, match="multiple"):
        ChunkedLoader(str(path), chunk=128, length=31)


@settings(max_examples=25, deadline=None)
@given(n_series=st.integers(1, 200), chunk=st.integers(1, 97))
def test_loader_callable_reader_ragged_final_chunk(n_series, chunk):
    """Property: a callable reader is asked for exactly the chunk grid —
    including the ragged final chunk — and the concatenation round-trips."""
    raw = random_walk(n_series, 16, seed=n_series)
    calls = []

    def reader(a, b):
        calls.append((a, b))
        return raw[a:b]

    loader = ChunkedLoader(reader, n_series, chunk=chunk)
    got = np.concatenate([np.asarray(c) for c in loader])
    np.testing.assert_array_equal(got, raw)
    assert len(loader) == len(calls) == -(-n_series // chunk)
    starts = list(range(0, n_series, chunk))
    assert calls == [(s, min(s + chunk, n_series)) for s in starts]
    # every chunk is full-sized except possibly the last (the ragged one)
    sizes = [b - a for a, b in calls]
    assert all(s == chunk for s in sizes[:-1])
    assert sizes[-1] == n_series - (len(calls) - 1) * chunk


def test_ids_are_permutation_with_padding():
    raw = jnp.asarray(random_walk(333, 64, seed=3))
    idx = core.build(raw, capacity=50)
    ids = np.asarray(idx.ids).ravel()
    real = ids[ids >= 0]
    assert sorted(real.tolist()) == list(range(333))
    assert (ids == -1).sum() == idx.n_blocks * idx.capacity - 333


def test_padding_never_wins():
    raw = jnp.asarray(random_walk(100, 64, seed=4))
    idx = core.build(raw, capacity=64)      # forces padding
    res = core.search(idx, raw[:8])
    assert (np.asarray(res.idx) >= 0).all()


def test_envelopes_planar_match_members():
    raw = jnp.asarray(random_walk(256, 64, seed=5))
    idx = core.build(raw, capacity=32)
    elo = np.asarray(idx.elo)               # (w, B)
    slo = np.asarray(idx.slo)               # (B, w, C)
    ids = np.asarray(idx.ids)
    for b in range(idx.n_blocks):
        real = ids[b] >= 0
        if real.any():
            np.testing.assert_allclose(
                elo[:, b], slo[b][:, real].min(axis=1), rtol=1e-6)


def test_flat_view_roundtrip():
    raw = jnp.asarray(random_walk(256, 64, seed=6))
    idx = core.build(raw, capacity=32)
    fv = flat_view(idx)
    assert fv.raw.shape == (idx.n_blocks * idx.capacity, 64)
    ids = np.asarray(fv.ids)
    assert sorted(ids[ids >= 0].tolist()) == list(range(256))


def test_capacity_larger_than_dataset():
    raw = jnp.asarray(random_walk(10, 64, seed=7))
    idx = core.build(raw, capacity=512)
    assert idx.capacity == 10
    res = core.search(idx, raw[:2])
    assert np.array_equal(np.asarray(res.idx[:, 0]), [0, 1])


@pytest.mark.parametrize("w,card", [(8, 16), (16, 256), (32, 4)])
def test_build_other_cardinalities(w, card):
    raw = jnp.asarray(random_walk(128, 64, seed=8))
    idx = core.build(raw, capacity=16, w=w, card=card)
    from repro.core.ucr import search_scan
    res = core.search(idx, raw[:4])
    want = search_scan(raw, raw[:4])
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
    # self-queries: near-zero distances carry O(sqrt(eps)) noise in the
    # expanded-form L2 (see kernels/batch_l2.py), so tolerance is absolute
    np.testing.assert_allclose(np.asarray(res.dist), np.asarray(want.dist),
                               atol=2e-2)
