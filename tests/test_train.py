"""Training substrate: convergence, fault tolerance, optimizers, checkpoint,
gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import common, transformer as T
from repro.train import (Checkpointer, make_train_step, opt_init)
from repro.train import compression, optimizer as opt_lib

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def patterned_batch(cfg, b=8, s=64):
    start = RNG.integers(0, cfg.vocab, (b, 1))
    toks = (start + 7 * np.arange(s)[None, :]) % cfg.vocab
    return {"tokens": jnp.asarray(toks, dtype=jnp.int32)}


def test_loss_decreases():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    params = common.build_params(T.param_specs(cfg), KEY)
    opt = opt_init(cfg.optimizer, params)
    step = jax.jit(make_train_step(cfg, base_lr=1e-3, warmup=5,
                                   total_steps=100, microbatch=1))
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, patterned_batch(cfg))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.6 * losses[0], losses[::10]


def test_nan_step_skipped_params_intact():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    params = common.build_params(T.param_specs(cfg), KEY)
    params["embed"] = params["embed"].at[0, 0].set(jnp.nan)
    opt = opt_init(cfg.optimizer, params)
    step = jax.jit(make_train_step(cfg, microbatch=1))
    batch = patterned_batch(cfg)
    batch["tokens"] = batch["tokens"].at[:, 0].set(0)   # hit the NaN row
    p2, o2, m = step(params, opt, batch)
    assert int(m["skipped"]) == 1
    np.testing.assert_array_equal(np.asarray(p2["final_norm"]),
                                  np.asarray(params["final_norm"]))
    # step counter still advances (no livelock on a persistent bad batch)
    assert int(o2.step) == 1


def test_microbatch_equivalence():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    params = common.build_params(T.param_specs(cfg), KEY)
    opt = opt_init(cfg.optimizer, params)
    batch = patterned_batch(cfg)
    s1 = jax.jit(make_train_step(cfg, microbatch=1))
    s4 = jax.jit(make_train_step(cfg, microbatch=4))
    p1, _, _ = s1(params, opt, batch)
    p4, _, _ = s4(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_adamw_matches_reference_math():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.1], [-0.2, 0.3]])}
    st = opt_lib.adamw_init(p)
    p2, st2 = opt_lib.adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.95,
                                   eps=1e-8, wd=0.0)
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    want = np.asarray(p["w"]) - 0.1 * (m / 0.1) / (np.sqrt(v / 0.05) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


def test_adafactor_memory_is_factored():
    p = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((64,))}
    st = opt_lib.adafactor_init(p)
    assert st.vr["w"].shape == (128,)
    assert st.vc["w"].shape == (256,)
    assert st.v["w"].shape == (0,)          # sentinel
    assert st.v["b"].shape == (64,)
    g = jax.tree.map(jnp.ones_like, p)
    p2, st2 = opt_lib.adafactor_update(g, st, p, lr=1e-2)
    assert all(np.all(np.isfinite(l)) for l in jax.tree.leaves(p2))


def test_quadratic_converges_with_int8_compression():
    """Error feedback keeps a quadratic converging despite 8-bit grads."""
    w = jnp.asarray([3.0, -2.0, 1.5, 8.0])
    err = jnp.zeros_like(w)
    lr = 0.05
    for i in range(300):
        g = 2 * w                                   # d/dw ||w||^2
        q, s, err = compression.compress_with_feedback(g, err)
        w = w - lr * compression.dequant8(q, s)
    assert float(jnp.max(jnp.abs(w))) < 1e-2, w


def test_quantize_roundtrip_error_bounded():
    g = jnp.asarray(RNG.standard_normal(1000).astype(np.float32)) * 5
    q, s = compression.quantize8(g)
    back = compression.dequant8(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


def test_checkpointer_atomic_keep_and_resume():
    cfg = get_config("rwkv6-7b", smoke=True)
    params = common.build_params(T.param_specs(cfg), KEY)
    opt = opt_init(cfg.optimizer, params)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 5, 9):
            ck.save(s, {"params": params, "opt": opt})
        ck.wait()
        assert ck.all_steps() == [5, 9]             # keep-last-2
        tmpl = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            {"params": params, "opt": opt})
        back = ck.restore(tmpl)
        for a, b in zip(jax.tree.leaves(back["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # no tmp dirs left behind
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_checkpointer_rejects_shape_mismatch():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_writes=False)
        ck.save(0, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ValueError):
            ck.restore({"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})


def test_lr_schedule_shape():
    from repro.train.step import lr_schedule
    lrs = [float(lr_schedule(jnp.asarray(s), base_lr=1e-3, warmup=10,
                             total=100)) for s in range(100)]
    assert abs(lrs[0] - 1e-4) < 1e-9           # first update is nonzero
    assert abs(lrs[9] - 1e-3) < 1e-9           # end of warmup
    assert lrs[99] < lrs[50] < lrs[9]
    assert lrs[99] >= 1e-4 - 1e-9              # min_frac floor
