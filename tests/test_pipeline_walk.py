"""The depth-D / group-G pipelined cached walk (DESIGN.md §5).

The pipeline is pure overlap: every (pipeline_depth, group_blocks)
setting must answer bit-identically to the serial walk — the knobs
trade speculative I/O and sync cadence for latency, never results.
These tests pin that contract across the engine matrix (ED/DTW/Cosine),
the anytime/deadline path, the coalesced multi-tenant drain, and the
two-round prepared protocol, plus the accounting invariants (at-most-
once disk reads under depth-D speculation) and the amortization the
pipeline exists for (threshold syncs ~= refined blocks / G).
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from repro import storage
from repro.core import engine, vector
from repro.core.ucr import search_scan
from repro.data import random_walk

N, LEN, CAP, R = 2000, 128, 64, 4

# the ISSUE's exactness grid: (pipeline_depth, group_blocks)
GRID = [(d, g) for d in (1, 2, 4) for g in (1, 2, 8)]


@pytest.fixture(scope="module")
def dataset():
    raw = random_walk(N, LEN, seed=41)
    rng = np.random.default_rng(13)
    qs = jnp.asarray(raw[rng.choice(N, 8, replace=False)]
                     + 0.05 * rng.standard_normal((8, LEN))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def opened(dataset, tmp_path_factory):
    raw, _ = dataset
    idx = core.build(jnp.asarray(raw), capacity=CAP)
    path = tmp_path_factory.mktemp("pipeline") / "rw.dsix"
    storage.save_index(idx, path)
    return storage.open_index(path)


@pytest.fixture(scope="module")
def vec_opened(tmp_path_factory):
    rng = np.random.default_rng(5)
    embs = jnp.asarray(rng.standard_normal((1024, 64)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((6, 64)).astype(np.float32))
    vidx = vector.build_vector_index(embs, capacity=64)
    path = tmp_path_factory.mktemp("pipeline") / "vec.dsix"
    storage.save_index(vidx, path)
    return storage.open_index(path), qs


def _search(opened, qs, *, d, g, metric=None, k=5, readers=2):
    with storage.SearchSession(opened, cache_blocks=opened.n_blocks,
                               readers=readers, pipeline_depth=d,
                               group_blocks=g) as sess:
        res = sess.search(qs, k=k, metric=metric)
        tel = sess.last_telemetry
    return res, tel


def _bitwise(got, want, *, stats=True):
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    assert np.array_equal(np.asarray(got.dist), np.asarray(want.dist))
    if stats:
        for f in ("blocks_visited", "series_refined", "lb_series"):
            assert np.array_equal(np.asarray(getattr(got.stats, f)),
                                  np.asarray(getattr(want.stats, f))), f


# ---------------------------------------------------------------------------
# the exactness grid: dist/idx bitwise vs the serial walk, all metrics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serial_goldens(dataset, opened, vec_opened):
    raw, qs = dataset
    vidx, vqs = vec_opened
    ed, _ = _search(opened, qs, d=1, g=1)
    dtw, _ = _search(opened, qs, d=1, g=1, metric=engine.DTW(r=R))
    cos, _ = _search(vidx, vqs, d=1, g=1, metric=engine.Cosine())
    # anchor the golden itself against the scan oracle
    want = search_scan(jnp.asarray(raw), qs, k=5)
    assert np.array_equal(np.asarray(ed.idx), np.asarray(want.idx))
    return ed, dtw, cos


@pytest.mark.parametrize("d,g", GRID)
def test_exactness_grid_ed(dataset, opened, serial_goldens, d, g):
    _, qs = dataset
    got, _ = _search(opened, qs, d=d, g=g)
    _bitwise(got, serial_goldens[0])


@pytest.mark.parametrize("d,g", GRID)
def test_exactness_grid_dtw(dataset, opened, serial_goldens, d, g):
    _, qs = dataset
    got, _ = _search(opened, qs, d=d, g=g, metric=engine.DTW(r=R))
    _bitwise(got, serial_goldens[1])


@pytest.mark.parametrize("d,g", GRID)
def test_exactness_grid_cosine(vec_opened, serial_goldens, d, g):
    vidx, vqs = vec_opened
    got, _ = _search(vidx, vqs, d=d, g=g, metric=engine.Cosine())
    _bitwise(got, serial_goldens[2])


def test_d1_g1_bit_identical_including_stats_and_io(dataset, opened):
    """(D=1, G=1) is today's walk byte for byte: same dispatch sequence,
    same fetch/speculate call order, so stats AND the I/O bill match the
    pre-pipeline session exactly (the session default IS (1, 1))."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=8) as sess:
        want = sess.search(qs, k=5)
    got, tel = _search(opened, qs, d=1, g=1)
    _bitwise(got, want)
    assert got.io.cache_hits == want.io.cache_hits
    assert got.io.blocks_refined == want.io.blocks_refined
    # serial cadence: one dispatch and one sync per walked block
    assert tel["syncs"] == tel["walk_blocks"] + 1
    assert tel["dispatches"] == tel["walk_blocks"]
    assert tel["stage_a_dispatches"] == tel["stage_a_blocks"]


# ---------------------------------------------------------------------------
# anytime/deadline + resume under batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("deadline", [1, 3, 7])
def test_deadline_cut_and_refine_to_exact_parity(dataset, opened, deadline):
    """deadline_blocks still counts BLOCKS under batching: a partial
    final group is cut to fit, so the anytime answer, its certificate,
    and the refine_to_exact continuation are all bit-identical to the
    serial session's."""
    _, qs = dataset
    with storage.SearchSession(opened, cache_blocks=8) as sess:
        a_ser = sess.search(qs, k=5, deadline_blocks=deadline)
        e_ser = a_ser.refine_to_exact()
    with storage.SearchSession(opened, cache_blocks=16, pipeline_depth=2,
                               group_blocks=4) as sess:
        a_pip = sess.search(qs, k=5, deadline_blocks=deadline)
        assert sess.last_telemetry["walk_blocks"] <= deadline
        e_pip = a_pip.refine_to_exact()
    _bitwise(a_pip, a_ser)
    for f in ("upper", "lower", "exact", "blocks_deferred"):
        assert np.array_equal(getattr(a_pip.certificate, f),
                              getattr(a_ser.certificate, f)), f
    _bitwise(e_pip, e_ser)


def test_prepared_two_round_protocol_under_batching(dataset, opened):
    """Round 1 (stage A) -> round 2 (resumed walk), both pipelined,
    equals the serial protocol bitwise — PreparedRound stays an exact
    resume point under grouping."""
    _, qs = dataset

    def protocol(d, g):
        with storage.SearchSession(opened, cache_blocks=16,
                                   pipeline_depth=d,
                                   group_blocks=g) as sess:
            prep = sess.approximate_threshold(qs, k=3)
            return sess.search(qs, k=3, prepared=prep,
                               initial_threshold=jnp.asarray(prep.threshold))

    want = protocol(1, 1)
    got = protocol(4, 8)
    _bitwise(got, want)


# ---------------------------------------------------------------------------
# coalesced multi-tenant drain through a pipelined session
# ---------------------------------------------------------------------------

def test_coalesced_drain_parity_with_pipelined_sessions(dataset, opened):
    """N tenants through one pipelined coalesced drain answer exactly
    what each would get from its own serial session — the walk's
    grouped dispatches and stale-threshold picks never leak into
    results.  (Work counters are NOT compared: the coalesced walk's
    fetch order is threshold-dynamic, so grouping can change which
    interleave produced the same exact answer — unlike ``run_cached``'s
    static schedule, where stats stay bitwise too.)"""
    _, qs = dataset
    batches = [(qs[0:3], dict(k=5)),
               (qs[3:6], dict(k=3, metric=engine.DTW(r=R))),
               (qs[6:8], dict(k=2))]
    want = []
    for q, kw in batches:
        with storage.SearchSession(opened, cache_blocks=64) as sess:
            want.append(sess.search(q, **kw))
    with storage.SearchSession(opened, cache_blocks=64) as sess:
        serial = [t.result() for t in
                  [sess.submit(q, **kw) for q, kw in batches]]
    with storage.SearchSession(opened, cache_blocks=64, readers=3,
                               pipeline_depth=2, group_blocks=4) as sess:
        got = [t.result() for t in
               [sess.submit(q, **kw) for q, kw in batches]]
    for g, s, w in zip(got, serial, want):
        _bitwise(g, w, stats=False)        # vs each tenant alone
        _bitwise(g, s, stats=False)        # vs the serial drain


# ---------------------------------------------------------------------------
# accounting under depth-D speculation
# ---------------------------------------------------------------------------

def test_at_most_once_billing_with_depth_speculation(dataset, opened):
    """Depth-D speculation may race group fetches through the reader
    pool, but the id-keyed cache still reads any block from disk at
    most once per batch, and the bill counts exactly those reads."""
    _, qs = dataset
    calls: list[int] = []
    orig = opened.host_raw.fetch
    opened.host_raw.fetch = lambda b: (calls.append(int(b)), orig(b))[1]
    try:
        with storage.SearchSession(opened, cache_blocks=opened.n_blocks,
                                   readers=3, pipeline_depth=4,
                                   group_blocks=2) as sess:
            res = sess.search(qs, k=5)
    finally:
        del opened.host_raw.fetch          # restore the class method
    counts = np.bincount(calls, minlength=opened.n_blocks)
    assert counts.max() <= 1, f"block(s) read twice in one batch: " \
        f"{np.nonzero(counts > 1)[0].tolist()}"
    assert res.io.blocks_fetched == len(calls)
    assert res.io.bytes_read == len(calls) * opened.host_raw.block_nbytes
    # the overshoot split is consistent: every refined block was touched
    assert res.io.blocks_refined <= res.io.blocks_fetched + res.io.cache_hits


# ---------------------------------------------------------------------------
# the amortization itself
# ---------------------------------------------------------------------------

def test_group_batching_amortizes_threshold_syncs(dataset, opened):
    """The acceptance criterion: G-block groups pay ~refined/G + 1
    threshold syncs instead of one per block, without changing what is
    refined."""
    _, qs = dataset
    res1, tel1 = _search(opened, qs, d=1, g=1)
    res8, tel8 = _search(opened, qs, d=1, g=8)
    _bitwise(res8, res1)
    assert tel1["syncs"] == tel1["walk_blocks"] + 1
    # every full group refines 8 blocks in one sync; only threshold
    # tightening mid-walk can shrink a group below G
    assert tel8["syncs"] <= max(-(-tel8["walk_blocks"] // 8) + 2,
                                tel8["walk_blocks"] // 4 + 1)
    assert tel8["syncs"] < tel1["syncs"]
    assert tel8["dispatches"] == tel8["syncs"] - 1


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------

def test_knob_validation(dataset, opened):
    _, qs = dataset
    with pytest.raises(ValueError, match=">= 1"):
        storage.SearchSession(opened, pipeline_depth=0)
    with pytest.raises(ValueError, match=">= 1"):
        storage.SearchSession(opened, group_blocks=0)
    with pytest.raises(ValueError, match="cover the pipeline"):
        storage.SearchSession(opened, cache_blocks=4, pipeline_depth=2,
                              group_blocks=4)
    with pytest.raises(ValueError, match="readers"):
        storage.BlockCache(opened.host_raw, 4, readers=0)
    with pytest.raises(ValueError, match="max_inflight"):
        storage.BlockCache(opened.host_raw, 4, max_inflight=0)
    with storage.SearchSession(opened, cache_blocks=4) as sess:
        with pytest.raises(ValueError, match=">= 1"):
            sess.search(qs, k=1, pipeline_depth=0)
        with pytest.raises(ValueError, match="cache capacity"):
            sess.search(qs, k=1, pipeline_depth=2, group_blocks=8)
    with pytest.raises(ValueError, match=">= 1"):
        engine.run_cached(opened, qs, engine.QueryPlan(
            metric=engine.ED(), schedule="block_major", k=1),
            fetch=lambda b: None, group_blocks=0)
