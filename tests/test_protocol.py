"""Two-round protocol round-1 reuse (DESIGN.md §6, ROADMAP "Reuse
round-1 prepare in round 2").

Round 2 resumes the round-1 ``PreparedSearch``/``PreparedRound`` instead
of recomputing it, so per protocol run: no block is fetched or refined
twice, answers stay bit-identical to the no-reuse protocol, the
touch-set is unified (no spurious round-2 warm hits), and round-1 reads
are billed to the consuming batch only — an abandoned round 1 cannot
pollute a later batch's ``IOStats``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as core
from repro import storage
from repro.core import distributed, engine
from repro.core import frontier as frontier_lib
from repro.core.ucr import search_scan
from repro.data import random_walk

KS = (1, 5, 32)
N, LEN, CAP = 2048, 128, 64


@pytest.fixture(scope="module")
def data():
    raw = random_walk(N, LEN, seed=31)
    rng = np.random.default_rng(7)
    qs = jnp.asarray(raw[rng.choice(N, 5, replace=False)]
                     + 0.05 * rng.standard_normal((5, LEN))
                     .astype(np.float32))
    return raw, qs


@pytest.fixture(scope="module")
def shard_paths(data, tmp_path_factory):
    raw, _ = data
    base = tmp_path_factory.mktemp("protocol")
    half = N // 2
    paths = []
    for s in range(2):
        ids = jnp.arange(s * half, (s + 1) * half, dtype=jnp.int32)
        sidx = core.build(jnp.asarray(raw[s * half:(s + 1) * half]),
                          capacity=CAP, ids=ids)
        path = base / f"shard{s}.dsix"
        storage.save_index(sidx, path)
        paths.append(path)
    return paths


def _sessions(paths, cache_blocks=8):
    return [storage.SearchSession(storage.open_index(p),
                                  cache_blocks=cache_blocks)
            for p in paths]


def _noreuse_protocol(sessions, qs, k):
    """The PR-4 protocol shape: threshold only, round 2 re-runs stage A."""
    thr_g = jnp.asarray(np.minimum.reduce(
        [np.asarray(s.approximate_threshold(qs, k=k)) for s in sessions]))
    results = [s.search(qs, k=k, initial_threshold=thr_g) for s in sessions]
    front = frontier_lib.Frontier(results[0].dist, results[0].idx)
    for r in results[1:]:
        front = frontier_lib.merge(front, frontier_lib.Frontier(r.dist,
                                                                r.idx))
    return front, results


class _Spy:
    """Count per-session cache touches and host-level refine dispatches."""

    def __init__(self, monkeypatch, sessions):
        self.gets: dict[int, list[int]] = {i: [] for i in
                                           range(len(sessions))}
        self.refines = 0
        for i, s in enumerate(sessions):
            orig = s.cache.get
            monkeypatch.setattr(
                s.cache, "get",
                lambda b, _o=orig, _log=self.gets[i]: (_log.append(int(b)),
                                                       _o(b))[1])
        orig_step = engine._cached_refine_step

        def counting_step(*a, **kw):
            self.refines += 1
            return orig_step(*a, **kw)

        monkeypatch.setattr(engine, "_cached_refine_step", counting_step)


# ---------------------------------------------------------------------------
# bit-stability: reuse is a strictly-tighter seed, not a different answer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", KS)
def test_ooc_protocol_bit_identical_to_noreuse(data, shard_paths, k):
    """``search_sharded_ooc`` (round-1 reuse) must answer bit-for-bit
    what the PR-4-shaped protocol (threshold only, stage A re-run in
    round 2) answers — and both must match the scan oracle's ids."""
    raw, qs = data
    reuse = _sessions(shard_paths)
    noreuse = _sessions(shard_paths)
    try:
        got = distributed.search_sharded_ooc(reuse, qs, k=k)
        front, _ = _noreuse_protocol(noreuse, qs, k)
    finally:
        for s in reuse + noreuse:
            s.close()
    assert np.array_equal(np.asarray(got.idx), np.asarray(front.ids))
    assert np.array_equal(np.asarray(got.dist), np.asarray(front.dists))
    want = search_scan(jnp.asarray(raw), qs, k=k)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))


# ---------------------------------------------------------------------------
# no double work: each block fetched and refined at most once per run
# ---------------------------------------------------------------------------

def test_no_block_refined_twice_per_protocol_run(data, shard_paths,
                                                 monkeypatch):
    """Per protocol run and per shard, every block id reaches the cache
    (and hence a ``panel_refine`` dispatch) at most once — round 2 never
    re-touches a stage-A block — and the host-level refine-step count is
    exactly the number of distinct blocks touched, i.e. the stats of a
    single-pass walk."""
    _, qs = data
    sessions = _sessions(shard_paths)
    spy = _Spy(monkeypatch, sessions)
    try:
        res = distributed.search_sharded_ooc(sessions, qs, k=5)
    finally:
        for s in sessions:
            s.close()
    total = 0
    for i, gets in spy.gets.items():
        counts = np.bincount(gets)
        assert counts.max() <= 1, \
            f"shard {i}: block(s) fetched twice in one protocol run: " \
            f"{np.nonzero(counts > 1)[0].tolist()}"
        total += len(gets)
    assert spy.refines == total
    # a refined block is billed exactly once: as a read or a warm hit
    assert res.io.blocks_fetched + res.io.cache_hits == total


def test_round2_never_rereads_stage_a_blocks(data, shard_paths,
                                             monkeypatch):
    """Zero round-2 re-reads of stage-A blocks: the blocks recorded in
    the round-1 prepared state never reach the cache again during the
    consuming search."""
    _, qs = data
    sessions = _sessions(shard_paths)
    try:
        preps = [s.approximate_threshold(qs, k=5) for s in sessions]
        thr_g = jnp.asarray(np.minimum.reduce([p.threshold for p in preps]))
        spy = _Spy(monkeypatch, sessions)        # instrument round 2 only
        for s, p in zip(sessions, preps):
            s.search(qs, k=5, initial_threshold=thr_g, prepared=p)
        for i, (s, p) in enumerate(zip(sessions, preps)):
            stage_a = set(p.state.refined)
            assert stage_a, "stage A refined no blocks?"
            again = stage_a & set(spy.gets[i])
            assert not again, \
                f"shard {i}: round 2 re-read stage-A block(s) {again}"
    finally:
        for s in sessions:
            s.close()


def test_protocol_strictly_fewer_refines_than_noreuse(data, shard_paths,
                                                      monkeypatch):
    """The reuse win, counted: the no-reuse protocol dispatches one
    extra refine per stage-A block (it refines them again in round 2 as
    warm cache hits); reuse drops exactly those."""
    _, qs = data
    reuse = _sessions(shard_paths)
    spy_new = _Spy(monkeypatch, reuse)
    try:
        distributed.search_sharded_ooc(reuse, qs, k=5)
    finally:
        for s in reuse:
            s.close()
    monkeypatch.undo()
    noreuse = _sessions(shard_paths)
    spy_old = _Spy(monkeypatch, noreuse)
    try:
        _noreuse_protocol(noreuse, qs, k=5)
    finally:
        for s in noreuse:
            s.close()
    assert spy_new.refines < spy_old.refines
    # old pays every stage-A block twice; reuse exactly removes those
    doubles = sum(np.sum(np.bincount(g) > 1) for g in spy_old.gets.values())
    assert spy_new.refines == spy_old.refines - doubles


# ---------------------------------------------------------------------------
# accounting: one touch-set and one bill per protocol run
# ---------------------------------------------------------------------------

def test_protocol_and_blind_run_report_same_accounting(shard_paths, data):
    """hit_rate skew regression: a single-shard protocol run is
    semantically identical to a blind ``search`` (its own threshold is
    the global one), so the session counters — hits, fetches, hit_rate —
    and the work stats must agree exactly.  Pre-fix, the protocol
    counted every stage-A block once more as a round-2 warm hit and
    re-billed its stage-A work in the stats."""
    _, qs = data
    with _sessions(shard_paths[:1])[0] as proto, \
            _sessions(shard_paths[:1])[0] as blind:
        for _ in range(2):                       # cold batch, then warm
            prep = proto.approximate_threshold(qs, k=5)
            r_p = proto.search(qs, k=5,
                               initial_threshold=jnp.asarray(prep.threshold),
                               prepared=prep)
            r_b = blind.search(qs, k=5)
            assert np.array_equal(np.asarray(r_p.idx), np.asarray(r_b.idx))
            assert r_p.io.blocks_fetched == r_b.io.blocks_fetched
            assert r_p.io.cache_hits == r_b.io.cache_hits
            assert r_p.io.bytes_read == r_b.io.bytes_read
            for g, w in zip(r_p.stats, r_b.stats):
                assert np.array_equal(np.asarray(g), np.asarray(w))
        assert proto.hit_rate == blind.hit_rate
        assert proto.cache_hits == blind.cache_hits
        assert proto.blocks_fetched == blind.blocks_fetched


def test_abandoned_round1_does_not_pollute_next_batch(shard_paths, data):
    """Carry-forward leakage regression: reads from a round 1 whose
    round 2 never runs are scoped to the dropped PreparedRound, not
    billed to the next unrelated batch."""
    raw, qs = data
    rng = np.random.default_rng(41)
    other = jnp.asarray(raw[rng.choice(N, 4, replace=False)]
                        + 0.05 * rng.standard_normal((4, LEN))
                        .astype(np.float32))
    with _sessions(shard_paths[:1])[0] as sess, \
            _sessions(shard_paths[:1])[0] as ref:
        abandoned = sess.approximate_threshold(qs, k=5)
        assert abandoned.carry_blocks > 0        # round 1 did read disk
        res = sess.search(other, k=5)            # unrelated batch
        want = ref.search(other, k=5)            # no round 1 before it
        assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
        # the abandoned reads appear in NO batch's bill...
        assert res.io.blocks_fetched + res.io.cache_hits \
            <= want.io.blocks_fetched
        assert res.io.bytes_read <= want.io.bytes_read
        # ...but the disk truly paid them (cache-level cumulative)
        assert sess.cache.disk_blocks \
            == res.io.blocks_fetched + abandoned.carry_blocks


def test_consumed_bill_includes_round1_reads(shard_paths, data):
    """The consuming batch's IOStats is the protocol's FULL disk cost:
    round-1 reads + round-2 reads, each block once."""
    _, qs = data
    with _sessions(shard_paths[:1])[0] as sess:
        prep = sess.approximate_threshold(qs, k=5)
        r1_reads = prep.carry_blocks
        assert r1_reads > 0
        res = sess.search(qs, k=5, prepared=prep)
        assert res.io.blocks_fetched == sess.cache.disk_blocks
        assert res.io.blocks_fetched >= r1_reads
        assert res.io.bytes_read \
            == res.io.blocks_fetched * sess.index.host_raw.block_nbytes


# ---------------------------------------------------------------------------
# prepared-state validation
# ---------------------------------------------------------------------------

def test_prepared_round_misuse_is_loud(shard_paths, data):
    raw, qs = data
    with _sessions(shard_paths[:1])[0] as sess, \
            _sessions(shard_paths[1:])[0] as other_sess:
        prep = sess.approximate_threshold(qs, k=5)
        with pytest.raises(ValueError, match="different SearchSession"):
            other_sess.search(qs, k=5, prepared=prep)
        with pytest.raises(ValueError, match="k/metric"):
            sess.search(qs, k=3, prepared=prep)
        other_qs = jnp.asarray(np.asarray(qs) + 1.0)
        with pytest.raises(ValueError, match="different query batch"):
            sess.search(other_qs, k=5, prepared=prep)
        sess.search(qs, k=5, prepared=prep)      # the one valid consume
        with pytest.raises(ValueError, match="already consumed"):
            sess.search(qs, k=5, prepared=prep)


def test_engine_prepared_validation(shard_paths, data):
    _, qs = data
    opened = storage.open_index(shard_paths[0])
    with storage.SearchSession(opened, cache_blocks=8) as sess:
        prep = sess.approximate_threshold(qs, k=5)
        with pytest.raises(ValueError, match="k="):
            engine.run_cached(opened, qs, engine.QueryPlan(k=3),
                              fetch=sess.cache.get, prepared=prep.state)


def test_device_run_rejects_mismatched_prepared(data):
    raw, qs = data
    idx = core.build(jnp.asarray(raw), capacity=CAP)
    prep = engine.prepare(engine.ED(), idx, qs, 5)
    with pytest.raises(ValueError, match="k="):
        engine.run(idx, qs, engine.QueryPlan(k=3), None, prep)
    with pytest.raises(ValueError, match="queries"):
        engine.run(idx, qs[:2], engine.QueryPlan(k=5), None, prep)
