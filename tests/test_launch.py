"""Launch layer: spec construction (no devices needed) + one real dry-run
cell on 512 fake devices as an integration test (subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs import get_config, list_archs, SHAPES
from repro.launch.specs import (batch_specs, cache_shapes, param_shapes,
                                runnable_shapes)


def test_batch_specs_shapes():
    cfg = get_config("h2o-danube-1.8b")
    b = batch_specs(cfg, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    cfg = get_config("whisper-medium")
    b = batch_specs(cfg, SHAPES["prefill_32k"])
    assert b["frames"].shape == (32, 32768, 1024)
    assert b["dec_tokens"].shape == (32, 448)
    cfg = get_config("pixtral-12b")
    b = batch_specs(cfg, SHAPES["train_4k"])
    assert b["patches"].shape == (256, 1024, 5120)
    assert b["tokens"].shape == (256, 4096 - 1024)


def test_param_shapes_no_allocation():
    cfg = get_config("nemotron-4-340b")
    shapes = param_shapes(cfg)
    total = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert 2.8e11 < total < 4.0e11          # ~340B without allocating


def test_cache_shapes_swa_ring_vs_full():
    cfg = get_config("gemma3-27b")
    cs = cache_shapes(cfg, 4, 32768)
    from repro.models.transformer import segments
    segs = segments(cfg)
    for seg, c in zip(segs, cs):
        want_s = 1024 if seg.kind == "swa" else 32768
        assert c["k"].shape == (seg.size, 4, want_s, 16, 128), seg


def test_runnable_shapes_long_rule():
    """long_500k only for sub-quadratic archs (assignment rule)."""
    runs_long = {a for a in list_archs()
                 if "long_500k" in runnable_shapes(get_config(a))}
    assert runs_long == {"h2o-danube-1.8b", "gemma3-27b", "hymba-1.5b",
                         "rwkv6-7b"}
    # every arch runs the other three cells
    for a in list_archs():
        rs = runnable_shapes(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(rs)


def test_total_cell_count_is_34():
    total = sum(len(runnable_shapes(get_config(a))) for a in list_archs())
    assert total == 34                      # 40 assigned minus 6 long skips


def test_mesh_function_shapes():
    run_subprocess("""
from repro.launch.mesh import make_production_mesh, data_axes_of
m1 = make_production_mesh()
assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
assert m2.axis_names == ("pod", "data", "model")
assert data_axes_of(m2) == ("pod", "data")
print("OK")
""", devices=512)


@pytest.mark.slow
def test_dryrun_one_cell_end_to_end():
    """Integration: a real 512-device lower+compile of one full-size cell."""
    out = run_subprocess("""
from repro.launch.dryrun import run_cell
rec = run_cell("h2o-danube-1.8b", "decode_32k", multi_pod=True)
assert rec["status"] == "ok"
assert rec["chips"] == 512
assert rec["bytes_per_device"]["peak"] > 0
assert rec["flops_per_dev"] > 0
print("OK")
""", devices=512, timeout=900)
    assert "OK" in out
