"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isax
from repro.kernels import ref
from repro.kernels.batch_l2 import batch_l2
from repro.kernels.isax_summarize import isax_summarize
from repro.kernels.lb_scan import lb_scan

RNG = np.random.default_rng(42)


def series(n, length, dtype=np.float32):
    return jnp.asarray(
        np.cumsum(RNG.standard_normal((n, length)), axis=1).astype(dtype))


@pytest.mark.parametrize("n,length", [(8, 64), (100, 128), (256, 256),
                                      (1000, 512), (37, 96)])
@pytest.mark.parametrize("w", [8, 16, 32])
def test_summarize_sweep(n, length, w):
    if length % w:
        pytest.skip("length % w != 0")
    x = series(n, length)
    paa_k, sax_k = isax_summarize(x, w=w, card=256, interpret=True)
    xn = isax.znorm(x)
    paa_r, sax_r = ref.paa_sax_ref(xn, w, 256)
    np.testing.assert_allclose(np.asarray(paa_k), np.asarray(paa_r),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(sax_k), np.asarray(sax_r))


@pytest.mark.parametrize("card", [4, 16, 64, 256])
def test_summarize_cardinalities(card):
    x = series(64, 128)
    _, sax_k = isax_summarize(x, w=16, card=card, interpret=True)
    xn = isax.znorm(x)
    _, sax_r = ref.paa_sax_ref(xn, 16, card)
    assert np.array_equal(np.asarray(sax_k), np.asarray(sax_r))
    assert int(jnp.max(sax_k)) < card and int(jnp.min(sax_k)) >= 0


@pytest.mark.parametrize("q,n", [(1, 128), (8, 512), (16, 1000), (5, 2048),
                                 (64, 64)])
@pytest.mark.parametrize("w", [8, 16])
def test_lb_scan_sweep(q, n, w):
    x = series(n, 128)
    qs = series(q, 128)
    _, sax, bounds = isax.summarize(x, w=w)
    q_paa = isax.paa(isax.znorm(qs), w)
    lo = bounds[..., 0].T
    hi = bounds[..., 1].T
    got = lb_scan(q_paa, lo, hi, n=128, interpret=True)
    want = ref.lb_series_ref(q_paa, bounds, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile_q,tile_n", [(2, 128), (8, 512), (16, 256)])
def test_lb_scan_tilings(tile_q, tile_n):
    x = series(300, 128)
    qs = series(7, 128)
    _, _, bounds = isax.summarize(x)
    q_paa = isax.paa(isax.znorm(qs), 16)
    got = lb_scan(q_paa, bounds[..., 0].T, bounds[..., 1].T, n=128,
                  tile_q=tile_q, tile_n=tile_n, interpret=True)
    want = ref.lb_series_ref(q_paa, bounds, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,n,length", [(4, 128, 64), (16, 512, 256),
                                        (3, 100, 128), (128, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batch_l2_sweep(q, n, length, dtype):
    x = series(n, length).astype(dtype)
    qs = series(q, length).astype(dtype)
    got = batch_l2(qs, x, interpret=True)
    want = ref.batch_l2_exact_ref(qs.astype(jnp.float32),
                                  x.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * np.max(np.asarray(want)))


def test_batch_l2_identity_zero():
    x = series(32, 128)
    d = batch_l2(x[:4], x, interpret=True)
    for i in range(4):
        assert float(d[i, i]) <= 1e-2
        assert int(jnp.argmin(d[i])) == i


@pytest.mark.parametrize("b,s,d,n", [(1, 16, 8, 4), (2, 32, 100, 16),
                                     (1, 64, 128, 8)])
def test_ssm_scan_kernel_vs_ref(b, s, d, n):
    from repro.kernels.ssm_scan import ssm_scan
    mk = lambda *sh: jnp.asarray(
        RNG.standard_normal(sh).astype(np.float32) * 0.5)
    xc, dt = mk(b, s, d), jnp.abs(mk(b, s, d)) * 0.2
    bm, cm = mk(b, s, n), mk(b, s, n)
    a_log = -jnp.abs(mk(d, n)) - 0.1
    got = ssm_scan(xc, dt, bm, cm, a_log, tile_d=32, interpret=True)
    want = ref.ssm_scan_ref(xc, dt, bm, cm, a_log)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_matches_mamba_layer_math():
    """The kernel's recurrence == models/mamba's (with matching coeffs)."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models import mamba, common as C

    class Cfg:
        n_layers = 1
        d_model = 32
        ssm_state = 8
        ssm_conv = 4
    p = jax.tree.map(lambda a: a[0],
                     C.build_params(mamba.param_specs(Cfg, 48),
                                    jax.random.PRNGKey(1)))
    x = jnp.asarray(RNG.standard_normal((2, 24, 32)).astype(np.float32) * .2)
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi = xz[..., :48]
    xc = jax.nn.silu(mamba._conv_causal(
        xi, p["conv"], jnp.zeros((2, 3, 48), x.dtype)))
    dt = jax.nn.softplus(xc * p["w_dt"][..., 0] + p["dt_bias"])
    bm = jnp.einsum("bsd,dn->bsn", xc, p["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", xc, p["w_c"])
    a_log = -jnp.exp(p["a_log"].astype(jnp.float32))
    y_k = ssm_scan(xc, dt, bm, cm, a_log, tile_d=16, interpret=True)
    # reference: h-scan part of mamba (before D-skip/gate/out-proj)
    a, bb, ct = mamba._ssm_coeffs(xc, p)
    hs, _ = mamba._chunk_scan(a, bb, jnp.zeros((2, 48, 8), jnp.float32))
    y_r = jnp.einsum("bsdn,bsn->bsd", hs, ct.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ops dispatch: the REPRO_KERNEL_MODE environment variable
# ---------------------------------------------------------------------------

def _mode_subprocess(mode):
    """Fresh interpreter importing repro.kernels.ops under the env var."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("REPRO_KERNEL_MODE", None)
    if mode is not None:
        env["REPRO_KERNEL_MODE"] = mode
    code = "from repro.kernels import ops; print(ops.get_mode())"
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=120)


@pytest.mark.parametrize("mode", ["ref", "interpret", "pallas", "auto"])
def test_kernel_mode_env_var_selects_mode(mode):
    r = _mode_subprocess(mode)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == mode


def test_kernel_mode_env_var_defaults_to_auto():
    r = _mode_subprocess(None)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == "auto"


def test_kernel_mode_env_var_rejects_junk_at_import():
    r = _mode_subprocess("jit-harder")
    assert r.returncode != 0, "junk mode must fail the import loudly"
    assert "REPRO_KERNEL_MODE" in r.stderr and "jit-harder" in r.stderr
    assert "auto" in r.stderr        # the error names the valid choices


def test_mode_ref_and_interpret_agree_through_dispatch():
    """Both dispatch paths of the ops layer on the same inputs: the jnp
    oracle (ref) vs interpret-mode Pallas — the per-kernel sweeps above
    call the kernels directly; this exercises ops.* dispatch itself."""
    from repro.kernels import ops
    x = series(96, 128)
    qs = series(4, 128)
    old = ops.get_mode()
    try:
        ops.set_mode("ref")
        paa_r, sax_r = ops.summarize(x, w=16, card=64)
        _, _, bounds = isax.summarize(x)
        q_paa = isax.paa(isax.znorm(qs), 16)
        lb_r = ops.lb_scan_planar(q_paa, bounds[..., 0].T, bounds[..., 1].T,
                                  n=128)
        d_r = ops.batch_l2(isax.znorm(qs), isax.znorm(x))
        ops.set_mode("interpret")
        paa_i, sax_i = ops.summarize(x, w=16, card=64)
        lb_i = ops.lb_scan_planar(q_paa, bounds[..., 0].T, bounds[..., 1].T,
                                  n=128)
        d_i = ops.batch_l2(isax.znorm(qs), isax.znorm(x))
    finally:
        ops.set_mode(old)
    assert np.array_equal(np.asarray(sax_r), np.asarray(sax_i))
    np.testing.assert_allclose(np.asarray(paa_r), np.asarray(paa_i),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lb_r), np.asarray(lb_i),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_i),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# block-local top-k select (kernels/block_topk.py)
# ---------------------------------------------------------------------------

INF = float(jnp.finfo(jnp.float32).max)


def masked_panel(q, c, frac_dead=0.3, quantize=None):
    """A (d, ids) panel under the engine's masking contract: distinct
    ids >= 0 on live lanes, (INF, -1) on dead ones."""
    d = np.abs(RNG.standard_normal((q, c))).astype(np.float32)
    if quantize:
        d = np.round(d * quantize).astype(np.float32) / quantize  # ties
    ids = np.tile(np.arange(c, dtype=np.int32), (q, 1))
    dead = RNG.random((q, c)) < frac_dead
    d[dead] = INF
    ids[dead] = -1
    return jnp.asarray(d), jnp.asarray(ids)


def frontier_oracle(d, ids, k):
    """topk via core.frontier's own lexsort (the tie-break contract)."""
    from repro.core import frontier as frontier_lib
    sd, si = frontier_lib._topk_by_dist_id(d, ids, k)
    return sd, jnp.where(sd < INF, si, -1)


@pytest.mark.parametrize("q,c", [(1, 128), (3, 37), (8, 256), (16, 1000)])
@pytest.mark.parametrize("k", [1, 5, 32])
def test_block_topk_sweep(q, c, k):
    from repro.kernels.block_topk import block_topk
    if k > c:
        pytest.skip("k > C is the ref-fallback path (tested separately)")
    d, ids = masked_panel(q, c)
    gd, gi = block_topk(d, ids, k=k, interpret=True)
    wd, wi = ref.block_topk_ref(d, ids, k)
    assert np.array_equal(np.asarray(gd), np.asarray(wd))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    fd, fi = frontier_oracle(d, ids, k)
    assert np.array_equal(np.asarray(gd), np.asarray(fd))
    assert np.array_equal(np.asarray(gi), np.asarray(fi))


@pytest.mark.parametrize("tile_q,tile_c", [(1, 128), (4, 128), (8, 256),
                                           (16, 1024)])
def test_block_topk_tilings(tile_q, tile_c):
    from repro.kernels.block_topk import block_topk
    d, ids = masked_panel(7, 300)
    gd, gi = block_topk(d, ids, k=5, tile_q=tile_q, tile_c=tile_c,
                        interpret=True)
    wd, wi = ref.block_topk_ref(d, ids, 5)
    assert np.array_equal(np.asarray(gd), np.asarray(wd))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))


def test_block_topk_tie_break_toward_smaller_id():
    """Quantized distances force exact ties: (dist, id)-lex order must
    match the frontier's lexsort bit-for-bit."""
    from repro.kernels.block_topk import block_topk
    d, ids = masked_panel(5, 400, quantize=4)     # ~4 distinct values
    for k in (1, 8):
        gd, gi = block_topk(d, ids, k=k, interpret=True)
        fd, fi = frontier_oracle(d, ids, k)
        assert np.array_equal(np.asarray(gd), np.asarray(fd))
        assert np.array_equal(np.asarray(gi), np.asarray(fi))


def test_block_topk_all_dead_rows():
    from repro.kernels.block_topk import block_topk
    d, ids = masked_panel(4, 200, frac_dead=1.0)
    gd, gi = block_topk(d, ids, k=6, interpret=True)
    assert np.all(np.asarray(gd) == INF)
    assert np.all(np.asarray(gi) == -1)


def test_block_topk_k_exceeds_candidates():
    """ops dispatch falls back to the padded oracle when k > C."""
    from repro.kernels import ops
    d, ids = masked_panel(3, 8, frac_dead=0.0)
    with ops.kernel_mode("interpret"):
        gd, gi = ops.block_topk(d, ids, 32)
    wd, wi = ref.block_topk_ref(d, ids, 32)
    assert gd.shape == (3, 32)
    assert np.array_equal(np.asarray(gd), np.asarray(wd))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.all(np.asarray(gd[:, 8:]) == INF)
    assert np.all(np.asarray(gi[:, 8:]) == -1)


# ---------------------------------------------------------------------------
# fused LB + distance + select (kernels/fused_refine.py)
# ---------------------------------------------------------------------------

def _fused_inputs(q, c, n, w=16, thr_val=50.0, inactive=()):
    x = series(c, n)
    qs = series(q, n)
    xn, qn = isax.znorm(x), isax.znorm(qs)
    _, _, bounds = isax.summarize(xn, w=w)
    q_paa = isax.paa(qn, w)
    thr = np.full((q,), thr_val, np.float32)
    for i in inactive:
        thr[i] = -np.inf                  # the folded ``active`` mask
    return (qn, q_paa, xn, bounds[..., 0].T, bounds[..., 1].T,
            jnp.arange(c, dtype=jnp.int32), jnp.asarray(thr))


@pytest.mark.parametrize("q,c,n", [(1, 130, 64), (5, 150, 128), (8, 256, 128),
                                   (3, 300, 96)])
@pytest.mark.parametrize("k", [1, 5])
def test_fused_refine_sweep(q, c, n, k):
    """Seeded float data: ids and live counts integer-exact, distances
    match the unfused oracle to float tolerance for any tiling."""
    from repro.kernels.fused_refine import fused_panel_topk
    args = _fused_inputs(q, c, n, inactive=(0,) if q > 2 else ())
    gd, gi, gn = fused_panel_topk(*args, k=k, n=n, interpret=True)
    wd, wi, wn = ref.fused_panel_topk_ref(*args, k=k, n=n)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.array_equal(np.asarray(gn), np.asarray(wn))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-5)


def test_fused_refine_bitwise_at_engine_tiling():
    """At the default (batch_l2-mirroring) tile sizes the distance tiles
    are the same dot on the same values — selected distances agree
    bit-for-bit with the oracle."""
    from repro.kernels.fused_refine import fused_panel_topk
    args = _fused_inputs(5, 150, 128)
    gd, gi, gn = fused_panel_topk(*args, k=5, n=128, interpret=True)
    wd, wi, wn = ref.fused_panel_topk_ref(*args, k=5, n=128)
    assert np.array_equal(np.asarray(gd), np.asarray(wd))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.array_equal(np.asarray(gn), np.asarray(wn))


@pytest.mark.parametrize("tile_q,tile_c", [(8, 128), (128, 256), (4, 512)])
def test_fused_refine_tilings(tile_q, tile_c):
    from repro.kernels.fused_refine import fused_panel_topk
    args = _fused_inputs(6, 330, 64)
    gd, gi, gn = fused_panel_topk(*args, k=3, n=64, tile_q=tile_q,
                                  tile_c=tile_c, interpret=True)
    wd, wi, wn = ref.fused_panel_topk_ref(*args, k=3, n=64)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.array_equal(np.asarray(gn), np.asarray(wn))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-5)


def test_fused_refine_all_pruned_and_inactive():
    """thr = 0 prunes every lane (lb >= 0 always); -inf rows are inactive
    queries.  Everything comes back (INF, -1) with zero live lanes —
    exactly what the engine's unfused path inserted."""
    from repro.kernels.fused_refine import fused_panel_topk
    args = list(_fused_inputs(4, 140, 64, thr_val=0.0, inactive=(2,)))
    gd, gi, gn = fused_panel_topk(*args, k=4, n=64, interpret=True)
    assert np.all(np.asarray(gd) == INF)
    assert np.all(np.asarray(gi) == -1)
    assert np.all(np.asarray(gn) == 0)


def test_fused_refine_padding_lanes_ignored():
    """ids < 0 lanes (block padding) never surface, even with huge thr."""
    from repro.kernels.fused_refine import fused_panel_topk
    args = list(_fused_inputs(3, 100, 64, thr_val=INF))
    ids = np.asarray(args[5]).copy()
    ids[60:] = -1                                 # pad tail of the block
    args[5] = jnp.asarray(ids)
    gd, gi, gn = fused_panel_topk(*args, k=8, n=64, interpret=True)
    wd, wi, wn = ref.fused_panel_topk_ref(*args, k=8, n=64)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.array_equal(np.asarray(gn), np.asarray(wn))
    assert np.all(np.asarray(gi) < 60)
    assert np.all(np.asarray(gn) == 60)


# ---------------------------------------------------------------------------
# banded-DTW wavefront (kernels/dtw_band.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,c,n", [(1, 130, 32), (4, 150, 64), (6, 256, 64)])
@pytest.mark.parametrize("r", [2, 7])
def test_dtw_band_panel_shared_bitwise(q, c, n, r):
    """Purely elementwise wavefront: kernel == lax.scan oracle
    BIT-FOR-BIT, shared-panel form."""
    from repro.kernels.dtw_band import dtw_band_panel
    x = isax.znorm(series(c, n))
    qs = isax.znorm(series(q, n))
    got = dtw_band_panel(qs, x, r=r, interpret=True)
    want = ref.dtw_band_ref(qs[:, None, :], x[None], r)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m", [40, 128, 300])
def test_dtw_band_panel_gathered_bitwise(m):
    from repro.kernels.dtw_band import dtw_band_panel
    xg = isax.znorm(series(3 * m, 48)).reshape(3, m, 48)
    qs = isax.znorm(series(3, 48))
    got = dtw_band_panel(qs, xg, r=5, interpret=True)
    want = ref.dtw_band_ref(qs[:, None, :], xg, 5)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tile_m", [128, 256, 512])
def test_dtw_band_panel_tilings(tile_m):
    from repro.kernels.dtw_band import dtw_band_panel
    x = isax.znorm(series(333, 32))
    qs = isax.znorm(series(2, 32))
    got = dtw_band_panel(qs, x, r=4, tile_m=tile_m, interpret=True)
    want = ref.dtw_band_ref(qs[:, None, :], x[None], 4)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_dtw_band_panel_zero_to_self():
    from repro.kernels.dtw_band import dtw_band_panel
    x = isax.znorm(series(16, 64))
    d = dtw_band_panel(x[:4], x, r=5, interpret=True)
    for i in range(4):
        assert float(d[i, i]) < 1e-6
        assert int(jnp.argmin(d[i])) == i


# ---------------------------------------------------------------------------
# kernel_mode: scoped dispatch with jit-cache invalidation
# ---------------------------------------------------------------------------

def test_kernel_mode_sets_and_restores():
    from repro.kernels import ops
    old = ops.get_mode()
    with ops.kernel_mode("ref"):
        assert ops.get_mode() == "ref"
        with ops.kernel_mode("interpret"):
            assert ops.get_mode() == "interpret"
        assert ops.get_mode() == "ref"
    assert ops.get_mode() == old


def test_kernel_mode_restores_on_exception():
    from repro.kernels import ops
    old = ops.get_mode()
    with pytest.raises(RuntimeError):
        with ops.kernel_mode("ref"):
            raise RuntimeError("boom")
    assert ops.get_mode() == old


def test_kernel_mode_clears_registered_jit_caches(monkeypatch):
    """The regression the context manager exists for: a jitted caller
    traced under one mode must NOT keep serving the stale kernel after
    the mode changes — set_mode without a cache clear would silently
    compare a kernel against itself in every mode-sweep test."""
    from repro.kernels import ops
    calls = []
    real = ops._batch_l2_kernel

    def spy(q, x, **kw):
        calls.append(kw)
        return real(q, x, **kw)

    monkeypatch.setattr(ops, "_batch_l2_kernel", spy)

    @jax.jit
    def f(q, x):
        return ops.batch_l2(q, x)

    ops.register_dispatch_cache(f)
    try:
        q, x = series(2, 64), series(16, 64)
        with ops.kernel_mode("ref"):
            f(q, x)
            assert not calls          # oracle path traced in
            with ops.kernel_mode("interpret"):
                f(q, x)               # stale cache would skip the kernel
            assert len(calls) == 1 and calls[0]["interpret"] is True
            f(q, x)                   # back under ref: retraced again
            assert len(calls) == 1
    finally:
        ops._DISPATCH_CACHES.remove(f)


# ---------------------------------------------------------------------------
# engine cell matrix: ref vs interpret through the full drivers
# ---------------------------------------------------------------------------

def _cell_fixtures():
    import repro.core as core
    from repro.core import vector
    from repro.data import random_walk
    raw = jnp.asarray(random_walk(192, 64, seed=21))
    rng = np.random.default_rng(22)
    qs = jnp.asarray(np.asarray(raw[:4])
                     + 0.05 * rng.standard_normal((4, 64)).astype(np.float32))
    idx = core.build(raw, capacity=32)
    fidx = core.build_flat(raw)
    embs = jnp.asarray(rng.standard_normal((256, 64)).astype(np.float32))
    vidx = vector.build_vector_index(embs, capacity=32)
    vq = embs[:4] + 0.01
    return dict(raw=raw, qs=qs, idx=idx, fidx=fidx, vidx=vidx, vq=vq)


_CELLS = {
    "ed_query_major": lambda c, core, D, vector:
        core.search(c["idx"], c["qs"], k=5),
    "ed_block_major": lambda c, core, D, vector:
        core.search_block_major(c["idx"], c["qs"], k=5),
    "ed_paris_flat": lambda c, core, D, vector:
        core.search_paris(c["idx"], c["qs"], k=5, chunk=64),
    "ed_ucr_scan": lambda c, core, D, vector:
        core.search_scan(c["raw"], c["qs"], k=5, chunk=64),
    "dtw_query_major": lambda c, core, D, vector:
        D.search_dtw(c["idx"], c["qs"], r=4, k=5),
    "dtw_flat": lambda c, core, D, vector:
        D.search_dtw_flat(c["fidx"], c["qs"], r=4, k=5, chunk=64),
    "cosine_query_major": lambda c, core, D, vector:
        vector.search_vectors(c["vidx"], c["vq"], k=5),
}


@pytest.fixture(scope="module")
def cells():
    return _cell_fixtures()


@pytest.mark.parametrize("cell", sorted(_CELLS))
def test_engine_cells_ref_vs_interpret(cells, cell):
    """The same public driver under both dispatch modes: identical
    neighbour ids and work stats, distances to float tolerance."""
    from repro.core import dtw as D
    from repro.core import vector
    import repro.core as core
    from repro.kernels import ops
    run = _CELLS[cell]
    with ops.kernel_mode("ref"):
        want = run(cells, core, D, vector)
    with ops.kernel_mode("interpret"):
        got = run(cells, core, D, vector)
    assert np.array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_allclose(np.asarray(got.dist), np.asarray(want.dist),
                               rtol=1e-5, atol=1e-5)
    for g, w in zip(got.stats, want.stats):
        assert np.array_equal(np.asarray(g), np.asarray(w)), cell


def test_refine_insert_width_is_k_not_capacity(monkeypatch):
    """The tentpole's frontier claim, proven on the live drivers: every
    insert during a search carries exactly k pre-selected candidates —
    the merge sorts K + k = 2k elements — never the C-wide panel."""
    import repro.core as core
    from repro.core import frontier as frontier_lib
    from repro.data import random_walk
    from repro.kernels import ops
    widths = []
    real = frontier_lib.insert_batch

    def spy(f, d, ids, **kw):
        widths.append(d.shape[-1])
        return real(f, d, ids, **kw)

    monkeypatch.setattr(frontier_lib, "insert_batch", spy)
    ops.clear_dispatch_caches()     # force retrace so the spy is seen
    try:
        raw = jnp.asarray(random_walk(128, 64, seed=30))
        idx = core.build(raw, capacity=32)
        k = 4
        for drv in (core.search_block_major, core.search):
            widths.clear()
            drv(idx, raw[:3], k=k)
            assert widths, "no inserts traced"
            assert max(widths) == k, (drv.__name__, widths)
        widths.clear()
        core.search_paris(idx, raw[:3], k=k, chunk=64)
        assert widths and max(widths) == k
    finally:
        ops.clear_dispatch_caches()  # drop spy-traced entries
