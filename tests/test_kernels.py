"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isax
from repro.kernels import ref
from repro.kernels.batch_l2 import batch_l2
from repro.kernels.isax_summarize import isax_summarize
from repro.kernels.lb_scan import lb_scan

RNG = np.random.default_rng(42)


def series(n, length, dtype=np.float32):
    return jnp.asarray(
        np.cumsum(RNG.standard_normal((n, length)), axis=1).astype(dtype))


@pytest.mark.parametrize("n,length", [(8, 64), (100, 128), (256, 256),
                                      (1000, 512), (37, 96)])
@pytest.mark.parametrize("w", [8, 16, 32])
def test_summarize_sweep(n, length, w):
    if length % w:
        pytest.skip("length % w != 0")
    x = series(n, length)
    paa_k, sax_k = isax_summarize(x, w=w, card=256, interpret=True)
    xn = isax.znorm(x)
    paa_r, sax_r = ref.paa_sax_ref(xn, w, 256)
    np.testing.assert_allclose(np.asarray(paa_k), np.asarray(paa_r),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(sax_k), np.asarray(sax_r))


@pytest.mark.parametrize("card", [4, 16, 64, 256])
def test_summarize_cardinalities(card):
    x = series(64, 128)
    _, sax_k = isax_summarize(x, w=16, card=card, interpret=True)
    xn = isax.znorm(x)
    _, sax_r = ref.paa_sax_ref(xn, 16, card)
    assert np.array_equal(np.asarray(sax_k), np.asarray(sax_r))
    assert int(jnp.max(sax_k)) < card and int(jnp.min(sax_k)) >= 0


@pytest.mark.parametrize("q,n", [(1, 128), (8, 512), (16, 1000), (5, 2048),
                                 (64, 64)])
@pytest.mark.parametrize("w", [8, 16])
def test_lb_scan_sweep(q, n, w):
    x = series(n, 128)
    qs = series(q, 128)
    _, sax, bounds = isax.summarize(x, w=w)
    q_paa = isax.paa(isax.znorm(qs), w)
    lo = bounds[..., 0].T
    hi = bounds[..., 1].T
    got = lb_scan(q_paa, lo, hi, n=128, interpret=True)
    want = ref.lb_series_ref(q_paa, bounds, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile_q,tile_n", [(2, 128), (8, 512), (16, 256)])
def test_lb_scan_tilings(tile_q, tile_n):
    x = series(300, 128)
    qs = series(7, 128)
    _, _, bounds = isax.summarize(x)
    q_paa = isax.paa(isax.znorm(qs), 16)
    got = lb_scan(q_paa, bounds[..., 0].T, bounds[..., 1].T, n=128,
                  tile_q=tile_q, tile_n=tile_n, interpret=True)
    want = ref.lb_series_ref(q_paa, bounds, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,n,length", [(4, 128, 64), (16, 512, 256),
                                        (3, 100, 128), (128, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batch_l2_sweep(q, n, length, dtype):
    x = series(n, length).astype(dtype)
    qs = series(q, length).astype(dtype)
    got = batch_l2(qs, x, interpret=True)
    want = ref.batch_l2_exact_ref(qs.astype(jnp.float32),
                                  x.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * np.max(np.asarray(want)))


def test_batch_l2_identity_zero():
    x = series(32, 128)
    d = batch_l2(x[:4], x, interpret=True)
    for i in range(4):
        assert float(d[i, i]) <= 1e-2
        assert int(jnp.argmin(d[i])) == i


@pytest.mark.parametrize("b,s,d,n", [(1, 16, 8, 4), (2, 32, 100, 16),
                                     (1, 64, 128, 8)])
def test_ssm_scan_kernel_vs_ref(b, s, d, n):
    from repro.kernels.ssm_scan import ssm_scan
    mk = lambda *sh: jnp.asarray(
        RNG.standard_normal(sh).astype(np.float32) * 0.5)
    xc, dt = mk(b, s, d), jnp.abs(mk(b, s, d)) * 0.2
    bm, cm = mk(b, s, n), mk(b, s, n)
    a_log = -jnp.abs(mk(d, n)) - 0.1
    got = ssm_scan(xc, dt, bm, cm, a_log, tile_d=32, interpret=True)
    want = ref.ssm_scan_ref(xc, dt, bm, cm, a_log)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_matches_mamba_layer_math():
    """The kernel's recurrence == models/mamba's (with matching coeffs)."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models import mamba, common as C

    class Cfg:
        n_layers = 1
        d_model = 32
        ssm_state = 8
        ssm_conv = 4
    p = jax.tree.map(lambda a: a[0],
                     C.build_params(mamba.param_specs(Cfg, 48),
                                    jax.random.PRNGKey(1)))
    x = jnp.asarray(RNG.standard_normal((2, 24, 32)).astype(np.float32) * .2)
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi = xz[..., :48]
    xc = jax.nn.silu(mamba._conv_causal(
        xi, p["conv"], jnp.zeros((2, 3, 48), x.dtype)))
    dt = jax.nn.softplus(xc * p["w_dt"][..., 0] + p["dt_bias"])
    bm = jnp.einsum("bsd,dn->bsn", xc, p["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", xc, p["w_c"])
    a_log = -jnp.exp(p["a_log"].astype(jnp.float32))
    y_k = ssm_scan(xc, dt, bm, cm, a_log, tile_d=16, interpret=True)
    # reference: h-scan part of mamba (before D-skip/gate/out-proj)
    a, bb, ct = mamba._ssm_coeffs(xc, p)
    hs, _ = mamba._chunk_scan(a, bb, jnp.zeros((2, 48, 8), jnp.float32))
    y_r = jnp.einsum("bsdn,bsn->bsd", hs, ct.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ops dispatch: the REPRO_KERNEL_MODE environment variable
# ---------------------------------------------------------------------------

def _mode_subprocess(mode):
    """Fresh interpreter importing repro.kernels.ops under the env var."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("REPRO_KERNEL_MODE", None)
    if mode is not None:
        env["REPRO_KERNEL_MODE"] = mode
    code = "from repro.kernels import ops; print(ops.get_mode())"
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=120)


@pytest.mark.parametrize("mode", ["ref", "interpret", "pallas", "auto"])
def test_kernel_mode_env_var_selects_mode(mode):
    r = _mode_subprocess(mode)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == mode


def test_kernel_mode_env_var_defaults_to_auto():
    r = _mode_subprocess(None)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == "auto"


def test_kernel_mode_env_var_rejects_junk_at_import():
    r = _mode_subprocess("jit-harder")
    assert r.returncode != 0, "junk mode must fail the import loudly"
    assert "REPRO_KERNEL_MODE" in r.stderr and "jit-harder" in r.stderr
    assert "auto" in r.stderr        # the error names the valid choices


def test_mode_ref_and_interpret_agree_through_dispatch():
    """Both dispatch paths of the ops layer on the same inputs: the jnp
    oracle (ref) vs interpret-mode Pallas — the per-kernel sweeps above
    call the kernels directly; this exercises ops.* dispatch itself."""
    from repro.kernels import ops
    x = series(96, 128)
    qs = series(4, 128)
    old = ops.get_mode()
    try:
        ops.set_mode("ref")
        paa_r, sax_r = ops.summarize(x, w=16, card=64)
        _, _, bounds = isax.summarize(x)
        q_paa = isax.paa(isax.znorm(qs), 16)
        lb_r = ops.lb_scan_planar(q_paa, bounds[..., 0].T, bounds[..., 1].T,
                                  n=128)
        d_r = ops.batch_l2(isax.znorm(qs), isax.znorm(x))
        ops.set_mode("interpret")
        paa_i, sax_i = ops.summarize(x, w=16, card=64)
        lb_i = ops.lb_scan_planar(q_paa, bounds[..., 0].T, bounds[..., 1].T,
                                  n=128)
        d_i = ops.batch_l2(isax.znorm(qs), isax.znorm(x))
    finally:
        ops.set_mode(old)
    assert np.array_equal(np.asarray(sax_r), np.asarray(sax_i))
    np.testing.assert_allclose(np.asarray(paa_r), np.asarray(paa_i),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lb_r), np.asarray(lb_i),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_i),
                               rtol=1e-4, atol=1e-4)
