"""Multi-device behaviour: sharded index build/search, elastic reshard,
MoE EP == local, seq-sharded flash decode, int8 DDP compression.

Each test runs in a fresh subprocess with 8 fake CPU devices (the device
count must be fixed before jax initializes, and the main pytest process
must keep seeing 1 device per the assignment rules)."""
import pytest

from conftest import run_subprocess


def test_sharded_build_and_search_exact():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, ucr
mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(1)
raw = np.cumsum(rng.standard_normal((4096, 128)).astype(np.float32), axis=1)
qs = np.cumsum(rng.standard_normal((8, 128)).astype(np.float32), axis=1)
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=128)
res = distributed.search_sharded(sidx, jnp.asarray(qs), mesh)
want = ucr.search_scan(jnp.asarray(raw), jnp.asarray(qs))
assert np.allclose(res.dist, want.dist, rtol=1e-4, atol=1e-4)
assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
res2 = distributed.search_sharded_scan(jnp.asarray(raw), jnp.asarray(qs), mesh)
assert np.allclose(res2.dist, want.dist, rtol=1e-4, atol=1e-4)
# k-NN: the two-round protocol agrees with the single-host oracle for k > 1
res_k = distributed.search_sharded(sidx, jnp.asarray(qs), mesh, k=8)
want_k = ucr.search_scan(jnp.asarray(raw), jnp.asarray(qs), k=8)
assert res_k.idx.shape == (8, 8)
assert np.array_equal(np.asarray(res_k.idx), np.asarray(want_k.idx))
assert np.allclose(res_k.dist, want_k.dist, rtol=1e-4, atol=1e-4)
print("OK")
""")


def test_index_checkpoint_elastic_reshard_8_to_4():
    run_subprocess("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed, ucr
from repro.train import Checkpointer
rng = np.random.default_rng(2)
raw = np.cumsum(rng.standard_normal((2048, 128)).astype(np.float32), axis=1)
qs = np.cumsum(rng.standard_normal((4, 128)).astype(np.float32), axis=1)

mesh8 = jax.make_mesh((8,), ("data",))
sidx = distributed.build_sharded(jnp.asarray(raw), mesh8, capacity=64)
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d, async_writes=False)
    ck.save(0, {"idx": sidx})
    # restore onto HALF the devices (elastic rescale) — same answers
    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    tmpl = {"idx": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), sidx)}
    specs = distributed.index_pspecs(mesh4, like=sidx)
    sh = {"idx": jax.tree.map(lambda s: NamedSharding(mesh4, s), specs,
          is_leaf=lambda x: isinstance(x, P))}
    back = ck.restore(tmpl, shardings=sh)["idx"]
    res = distributed.search_sharded(back, jnp.asarray(qs), mesh4)
    want = ucr.search_scan(jnp.asarray(raw), jnp.asarray(qs))
    assert np.allclose(res.dist, want.dist, rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx))
print("OK")
""")


def test_moe_ep_equals_local():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe, common
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
class C: n_layers=1; d_model=32; d_ff=64; n_experts=8
p = jax.tree.map(lambda a: a[0], common.build_params(moe.param_specs(C), key))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, 32)).astype(np.float32))
y_ep, aux_ep = jax.jit(lambda x: moe.moe_ffn_ep(
    x, p, top_k=2, capacity_factor=8.0, act=jax.nn.silu,
    mesh=mesh, data_axes=("data",)))(x)
# local reference: same capacity semantics PER SHARD -> use per-shard halves
y0, _ = moe.moe_ffn_local(x[:2].reshape(-1, 32), p, top_k=2,
                          capacity_factor=8.0, act=jax.nn.silu)
y1, _ = moe.moe_ffn_local(x[2:].reshape(-1, 32), p, top_k=2,
                          capacity_factor=8.0, act=jax.nn.silu)
want = jnp.concatenate([y0.reshape(2, 16, 32), y1.reshape(2, 16, 32)])
assert np.allclose(np.asarray(y_ep), np.asarray(want), rtol=2e-3, atol=2e-3), \
    np.max(np.abs(np.asarray(y_ep) - np.asarray(want)))
assert float(aux_ep.dropped_frac) == 0.0
print("OK")
""")


def test_seqsharded_flash_decode_equals_local():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import attention
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
B, S, H, KVH, hd = 1, 512, 4, 2, 16
q = jnp.asarray(rng.standard_normal((B, 1, H, hd)).astype(np.float32))
kn = jnp.asarray(rng.standard_normal((B, 1, KVH, hd)).astype(np.float32))
vn = jnp.asarray(rng.standard_normal((B, 1, KVH, hd)).astype(np.float32))
k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)).astype(np.float32))
v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)).astype(np.float32))
pos = jnp.asarray(300)
got, kc, vc = jax.jit(lambda q, kn, vn, k, v: attention.decode_attend_seqsharded(
    q, kn, vn, k, v, pos, mesh=mesh, axes=("data",), chunk=64))(q, kn, vn, k, v)
k2, v2 = attention.cache_update(k, v, kn, vn, pos)
want = attention.decode_attend(q, k2, v2, pos, chunk=64)
assert np.allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
assert np.allclose(np.asarray(kc), np.asarray(k2))  # write landed correctly
assert np.allclose(np.asarray(vc), np.asarray(v2))
print("OK")
""")


def test_ddp_int8_allreduce_mean():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.train import compression
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 64, 32)).astype(np.float32))
err = jnp.zeros_like(g)
mean, new_err = compression.ddp_allreduce_int8(
    {"w": g}, {"w": err}, mesh, ("data",))
want = np.mean(np.asarray(g), axis=0)
got = np.asarray(mean["w"])
# int8 quantization error is bounded by scale/2 per shard
scale = np.abs(np.asarray(g)).max(axis=(1, 2), keepdims=True) / 127
tol = float(scale.mean()) * 0.6
assert np.abs(got - want).max() < tol, (np.abs(got - want).max(), tol)
print("OK")
""")


def test_multidevice_train_step_runs():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import common, transformer as T
from repro.train import make_train_step, opt_init
from repro.launch.specs import param_pspecs
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("granite-moe-1b-a400m", smoke=True)
key = jax.random.PRNGKey(0)
params = common.build_params(T.param_specs(cfg), key)
pp = param_pspecs(cfg, mesh, ("data",))
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), pp,
    is_leaf=lambda x: isinstance(x, P)))
opt = opt_init(cfg.optimizer, params)
step = jax.jit(make_train_step(cfg, mesh=mesh, data_axes=("data",),
                               microbatch=1))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                               dtype=jnp.int32)}
losses = []
for _ in range(4):
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0]
assert int(m["skipped"]) == 0
print("OK", losses)
""")


def test_sharded_search_bit_identical_to_noreuse_protocol():
    """Round-1 reuse regression: threading the prepared state into
    ``engine.run`` must answer bit-for-bit — dist, idx, AND stats —
    what the PR-4 wrapper (round 2 recomputing ``engine.prepare``)
    answers."""
    run_subprocess("""
import functools, jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import distributed, engine
from repro.core.search import SearchResult, SearchStats
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(17)
raw = np.cumsum(rng.standard_normal((2048, 128)).astype(np.float32), axis=1)
qs = jnp.asarray(np.cumsum(
    rng.standard_normal((5, 128)).astype(np.float32), axis=1))
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=64)

k = 5
m = engine.ED()
plan = engine.QueryPlan(metric=m, schedule="block_major", k=k)
ax = ("data",)

def _search_noreuse(local_index, q):
    # the PR-4 protocol body: round 2 re-prepares instead of resuming
    prep = engine.prepare(m, local_index, q, k)
    thr_g = jax.lax.pmin(prep.front.threshold(), ax)
    res = engine.run(local_index, q, plan, initial_threshold=thr_g)
    dist_g, idx_g = distributed._merge_shards(res, ax)
    stats = SearchStats(
        blocks_visited=jax.lax.psum(res.stats.blocks_visited, ax),
        series_refined=jax.lax.psum(res.stats.series_refined, ax),
        lb_series=jax.lax.psum(res.stats.lb_series, ax),
        iters=jax.lax.pmax(res.stats.iters, ax))
    return SearchResult(dist=dist_g, idx=idx_g, stats=stats)

specs = distributed.index_pspecs(mesh, like=sidx)
out = SearchResult(dist=P(None), idx=P(None),
                   stats=SearchStats(blocks_visited=P(None),
                                     series_refined=P(None),
                                     lb_series=P(None), iters=P()))
old = shard_map(_search_noreuse, mesh=mesh, in_specs=(specs, P(None)),
                out_specs=out, check_vma=False)(sidx, qs)
new = distributed.search_sharded(sidx, qs, mesh, k=k)
assert np.array_equal(np.asarray(new.idx), np.asarray(old.idx))
assert np.array_equal(np.asarray(new.dist), np.asarray(old.dist))
for g, w in zip(new.stats, old.stats):
    assert np.array_equal(np.asarray(g), np.asarray(w))
print("OK")
""")


def test_sharded_dtw_exact_vs_scan_oracle():
    """ROADMAP cell: ``search_sharded(..., metric=DTW(r))`` — exact vs a
    brute-force banded-DTW scan, under shard_map, k in {1, 5, 32}."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, engine, isax
from repro.core import frontier as frontier_lib
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(19)
raw = np.cumsum(rng.standard_normal((1024, 64)).astype(np.float32), axis=1)
qs = jnp.asarray(raw[rng.choice(1024, 4, replace=False)]
                 + 0.1 * rng.standard_normal((4, 64)).astype(np.float32))
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=32)
r = 4
x = isax.znorm(jnp.asarray(raw))
q = isax.znorm(qs)
d = engine.dtw_band(q[:, None, :], x[None, :, :], r)       # (Q, N) squared
ids = jnp.broadcast_to(jnp.arange(1024, dtype=jnp.int32)[None], d.shape)
for k in (1, 5, 32):
    want = frontier_lib.init(q.shape[0], k).insert(d, ids)
    res = distributed.search_sharded(sidx, qs, mesh, k=k,
                                     metric=engine.DTW(r=r))
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.ids)), k
    assert np.allclose(np.asarray(res.dist),
                       np.sqrt(np.asarray(want.dists)),
                       rtol=1e-4, atol=1e-4), k
print("OK")
""")


def test_sharded_cosine_exact_vs_scan_oracle():
    """ROADMAP cell: ``search_sharded(..., metric=Cosine())`` over a
    sharded vector index built with normalize=False — exact vs the
    brute-force scan on prepped embeddings, k in {1, 5, 32}."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, engine, ucr
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(23)
embs = jnp.asarray(rng.standard_normal((1024, 64)).astype(np.float32))
qs = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
prepped = engine.prep_vectors(embs)
sidx = distributed.build_sharded(prepped, mesh, capacity=32,
                                 normalize=False)
for k in (1, 5, 32):
    res = distributed.search_sharded(sidx, qs, mesh, k=k,
                                     metric=engine.Cosine())
    want = ucr.search_scan(prepped, engine.prep_vectors(qs), k=k,
                           normalize=False)
    assert np.array_equal(np.asarray(res.idx), np.asarray(want.idx)), k
    assert np.allclose(np.asarray(res.dist), np.asarray(want.dist),
                       rtol=1e-4, atol=1e-4), k
print("OK")
""")


def test_anytime_deadline_under_shards():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, ucr
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(5)
raw = np.cumsum(rng.standard_normal((4096, 128)).astype(np.float32), axis=1)
qs = np.cumsum(rng.standard_normal((4, 128)).astype(np.float32), axis=1)
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=32)
exact = distributed.search_sharded(sidx, jnp.asarray(qs), mesh)
rough = distributed.search_sharded(sidx, jnp.asarray(qs), mesh,
                                   deadline_blocks=2)
assert (np.asarray(rough.dist) >= np.asarray(exact.dist) - 1e-5).all()
print("OK")
""")
