"""Hypothesis property tests for the paper's core invariants.

The whole exactness argument of ParIS/MESSI rests on: LB(q, S) <= ED(q, S)
for every stored series (no false dismissals), and block envelopes only ever
WIDEN per-series bounds.  These are the system invariants; everything else
(pruning order, scheduling) is performance.
"""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import index as index_lib
from repro.core import isax

W = 16


@st.composite
def series_batch(draw):
    """Seed-driven batches: mixture of walks, scaled noise, bursts, and
    near-constant rows — broad coverage without entropy-heavy float lists."""
    seed = draw(st.integers(0, 2 ** 31 - 1))
    n = draw(st.integers(2, 24))
    kind = draw(st.sampled_from(["walk", "noise", "burst", "flatish"]))
    scale = draw(st.sampled_from([1e-3, 1.0, 50.0]))
    r = np.random.default_rng(seed)
    if kind == "walk":
        x = np.cumsum(r.standard_normal((n, 64)), axis=1)
    elif kind == "noise":
        x = r.standard_normal((n, 64))
    elif kind == "burst":
        x = np.zeros((n, 64))
        pos = r.integers(0, 60, n)
        for i in range(n):
            x[i, pos[i]:pos[i] + 4] = r.standard_normal(4) * 5
        x += 0.01 * r.standard_normal((n, 64))
    else:
        x = np.ones((n, 64)) * r.standard_normal((n, 1))
        x[:, 0] += 1.0          # keep znorm well-defined
    return (x * scale).astype(np.float32)


@settings(max_examples=60, deadline=None)
@given(series_batch(), series_batch())
def test_lower_bound_never_exceeds_distance(xs, qs):
    """MINDIST(q_paa, bounds(S)) <= ||znorm(q) - znorm(S)||^2 (up to f32
    noise, which scales with the distance magnitude)."""
    x = isax.znorm(jnp.asarray(xs))
    q = isax.znorm(jnp.asarray(qs))
    _, _, bounds = isax.summarize(x, normalize=False)
    q_paa = isax.paa(q)
    lb = np.asarray(
        isax.mindist_paa_bounds_sq(q_paa[:, None, :], bounds[None], 64))
    d = np.asarray(jnp.sum((q[:, None, :] - x[None]) ** 2, axis=-1))
    assert np.all(lb <= d * (1 + 1e-5) + 1e-3), float(np.max(lb - d))


@settings(max_examples=50, deadline=None)
@given(series_batch())
def test_paa_lb_tighter_than_symbol_bounds(xs):
    """(n/w)||q_paa - s_paa||^2 >= MINDIST via regions (PAA is the limit of
    infinite cardinality) — and both lower-bound the true distance."""
    x = isax.znorm(jnp.asarray(xs))
    p, s, bounds = isax.summarize(x, normalize=False)
    q = x[:1]
    q_paa = p[:1]
    lb_region = isax.mindist_paa_bounds_sq(q_paa[:, None, :], bounds[None],
                                           64)
    lb_paa = isax.paa_lb_sq(q_paa[:, None, :], p[None], 64)
    assert np.all(np.asarray(lb_region) <= np.asarray(lb_paa) + 1e-3)


@settings(max_examples=30, deadline=None)
@given(series_batch())
def test_envelope_contains_members(xs):
    """Block envelope MINDIST <= every member's MINDIST (no false dismissal
    at the block level)."""
    x = jnp.asarray(xs)
    idx = index_lib.build(x, capacity=4)
    q = isax.znorm(x[:3])
    q_paa = isax.paa(q)
    # envelope lb per block
    env_lb = isax.mindist_paa_bounds_sq(
        q_paa[:, None, :],
        jnp.stack([idx.elo.T, idx.ehi.T], axis=-1)[None], idx.n)
    # member lb per block: (Q, B, C)
    member_bounds = jnp.stack([idx.slo, idx.shi], axis=-1)  # (B, w, C, 2)
    mb = jnp.transpose(member_bounds, (0, 2, 1, 3))         # (B, C, w, 2)
    mem_lb = isax.mindist_paa_bounds_sq(
        q_paa[:, None, None, :], mb[None], idx.n)           # (Q, B, C)
    real = np.asarray(idx.ids) >= 0
    e = np.asarray(env_lb)[:, :, None]
    m = np.asarray(mem_lb)
    viol = (e > m * (1 + 1e-5) + 1e-3) & real[None]
    assert not viol.any(), float(np.max((e - m) * real[None]))


@settings(max_examples=50, deadline=None)
@given(series_batch())
def test_sax_symbols_match_breakpoints(xs):
    """symbol s  <=>  value in [bp[s-1], bp[s])  (quantization correctness)."""
    x = isax.znorm(jnp.asarray(xs))
    p = isax.paa(x)
    s = isax.sax_from_paa(p)
    lo_t, hi_t = isax.region_tables(256)
    lo = np.asarray(lo_t)[np.asarray(s)]
    hi = np.asarray(hi_t)[np.asarray(s)]
    pv = np.asarray(p)
    assert np.all(pv >= lo - 1e-6)
    assert np.all(pv <= hi + 1e-6)


@settings(max_examples=20, deadline=None)
@given(series_batch())
def test_sort_order_groups_words(xs):
    """The interleaved sort puts identical iSAX words in contiguous runs."""
    x = jnp.asarray(xs)
    _, s, _ = isax.summarize(x)
    order = np.asarray(isax.sort_order(s))
    words = [tuple(row) for row in np.asarray(s)[order]]
    seen = set()
    prev = None
    for wrd in words:
        if wrd != prev:
            assert wrd not in seen, "word re-appeared after a break"
            seen.add(wrd)
            prev = wrd


def test_znorm_properties():
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((16, 128)).astype(np.float32) * 7 + 3)
    z = isax.znorm(x)
    np.testing.assert_allclose(np.asarray(jnp.mean(z, axis=1)), 0,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.std(z, axis=1)), 1, atol=1e-3)


def test_breakpoints_equiprobable():
    bps = isax.breakpoints(256)
    assert len(bps) == 255
    assert np.all(np.diff(bps) > 0)
    from scipy.stats import norm
    np.testing.assert_allclose(norm.cdf(bps),
                               np.arange(1, 256) / 256, atol=1e-6)
