"""Full similarity-search tour: the three systems of the paper, streaming
(ParIS+) ingestion, anytime answers, and the DTW extension.

    PYTHONPATH=src python examples/similarity_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core import dtw
from repro.core.paris import search_paris
from repro.core.ucr import search_scan
from repro.data import make_dataset
from repro.data.loader import build_streaming


def main():
    n = 60_000
    raw_np = make_dataset("seismic", n, 256)
    raw = jnp.asarray(raw_np)
    rng = np.random.default_rng(0)
    qs = jnp.asarray(raw_np[rng.choice(n, 8, replace=False)]
                     + 0.05 * rng.standard_normal((8, 256)).astype(np.float32))

    # -- ParIS+-style streaming build (ingest/compute overlap) -------------
    t0 = time.perf_counter()
    index = build_streaming(raw_np, chunk=1 << 15, capacity=1024)
    jax.block_until_ready(index.raw)
    print(f"streaming build (ParIS+ overlap): {time.perf_counter()-t0:.2f}s "
          f"for {n} series")

    # -- the three query systems -------------------------------------------
    from repro.core.search import search_block_major
    for name, fn in [("UCR-Suite-p", lambda: search_scan(raw, qs)),
                     ("ParIS", lambda: search_paris(index, qs)),
                     ("MESSI (paper)", lambda: core.search(index, qs)),
                     ("MESSI (block-major)",
                      lambda: search_block_major(index, qs))]:
        res = fn()
        jax.block_until_ready(res.dist)
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res.dist)
        dt = (time.perf_counter() - t0) / 8 * 1e3
        print(f"{name:20s} {dt:8.2f} ms/query   "
              f"refined {float(np.mean(np.asarray(res.stats.series_refined))):9.0f}"
              f" series/query")

    # -- k-NN result lists (same frontier machinery, any k) -----------------
    res_k = core.search(index, qs, k=5)
    print("top-5 ids for query 0:",
          [int(i) for i in np.asarray(res_k.idx[0])],
          "dists", [round(float(d), 3) for d in np.asarray(res_k.dist[0])])

    # -- anytime mode (straggler mitigation / deadline) ---------------------
    exact = core.search(index, qs)
    rough = core.search(index, qs, deadline_blocks=4)
    gap = np.asarray(rough.dist) / np.asarray(exact.dist) - 1
    print(f"anytime (4-block deadline): distance gap vs exact "
          f"mean {100*gap.mean():.2f}% max {100*gap.max():.2f}%")

    # -- DTW on the same index (paper SV) -----------------------------------
    res_d = dtw.search_dtw(index, qs[:2], r=6)
    print("DTW 1-NN (same index, banded):",
          [int(i) for i in np.asarray(res_d.idx[:, 0])])


if __name__ == "__main__":
    main()
