"""Train a reduced-config LM on synthetic tokens and watch the loss drop,
with a mid-run checkpoint + resume (the fault-tolerance path).

    PYTHONPATH=src python examples/train_lm.py [--arch h2o-danube-1.8b]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        print(f"== training {args.arch} (reduced config) for "
              f"{args.steps} steps ==")
        train_main(["--arch", args.arch, "--smoke",
                    "--steps", str(args.steps), "--batch", "8",
                    "--seq", "256", "--lr", "1e-3",
                    "--ckpt-dir", d, "--ckpt-every", "40"])
        print("\n== simulated preemption: resuming from the checkpoint ==")
        train_main(["--arch", args.arch, "--smoke",
                    "--steps", str(args.steps + 30), "--batch", "8",
                    "--seq", "256", "--lr", "1e-3",
                    "--ckpt-dir", d, "--ckpt-every", "40"])


if __name__ == "__main__":
    main()
