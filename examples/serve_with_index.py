"""End-to-end driver (the paper's kind: serving similarity search).

The paper's SV notes the technique "applies to high-dimensional vectors in
general ... such as deep-learning embeddings".  This example is that
application end to end:

  1. embed a corpus of token sequences with a (reduced) assigned LM,
  2. build the MESSI vector index over the embeddings,
  3. serve batched nearest-neighbour queries (new sequences -> embed ->
     exact cosine top-k result lists), with latency stats.

With ``--index-path`` the index persists across launches (DESIGN.md §5):
the first run builds and saves it; every later run skips the corpus
embedding + build entirely and OPENS the file out-of-core — summaries on
device, raw embeddings streamed from disk per query batch — which is how
a server cold-starts against an index far larger than device memory.

    PYTHONPATH=src python examples/serve_with_index.py [--arch rwkv6-7b] \\
        [--k 5] [--index-path /tmp/corpus.dsix]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import storage
from repro.configs import get_config
from repro.core import vector
from repro.models import common, transformer as T


def embed(params, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean-pooled final hidden state as the sequence embedding."""
    ctx = T.Ctx(cfg, None, (), "train")
    x = T.embed_inputs(params, {"tokens": tokens}, cfg, ctx)
    x, _, _ = T.decoder_stack(params, x, cfg, ctx)
    x = common.rmsnorm(x, params["final_norm"])
    return jnp.mean(x.astype(jnp.float32), axis=1)          # (B, d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--corpus", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k", type=int, default=5,
                    help="neighbours returned per query (exact top-k)")
    ap.add_argument("--index-path", default=None,
                    help="persisted index file: built+saved on first run, "
                         "opened out-of-core (no rebuild) afterwards")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = common.build_params(T.param_specs(cfg), key)
    rng = np.random.default_rng(0)

    # corpus: documents from 8 topical clusters (cluster = token offset)
    topics = rng.integers(0, 8, args.corpus)
    toks = ((topics[:, None] * 61 + rng.integers(0, 32,
             (args.corpus, args.seq))) % cfg.vocab).astype(np.int32)
    embed_fn = jax.jit(lambda p, t: embed(p, cfg, t))

    index = None
    if args.index_path and os.path.exists(args.index_path):
        extra = storage.read_meta(args.index_path)["extra"]
        # the embedding space is defined by (model, corpus): a mismatch on
        # either would silently serve neighbours from the wrong space
        want = {"kind": "vector", "corpus": args.corpus, "arch": args.arch}
        if {k: extra.get(k) for k in want} != want:
            raise SystemExit(f"{args.index_path} holds {extra}, not a "
                             f"vector index for {want} — delete it "
                             f"or pass a different --index-path")
        index = storage.open_index(args.index_path)
        print(f"opened {args.index_path} out-of-core: "
              f"{index.n_real} x {index.n} embeddings, "
              f"{index.n_blocks} blocks on disk")
    else:
        print(f"embedding {args.corpus} docs with {cfg.name} (reduced) ...")
        embs = []
        t0 = time.perf_counter()
        for i in range(0, args.corpus, 256):
            embs.append(embed_fn(params, jnp.asarray(toks[i:i + 256])))
        embs = jnp.concatenate(embs)
        jax.block_until_ready(embs)
        print(f"  {time.perf_counter()-t0:.1f}s -> embeddings {embs.shape}")

        print("building MESSI vector index ...")
        index = vector.build_vector_index(embs, capacity=256)
        if args.index_path:
            storage.save_index(index, args.index_path,
                               extra={"kind": "vector", "dim": embs.shape[-1],
                                      "corpus": args.corpus,
                                      "arch": args.arch})
            print(f"saved index -> {args.index_path} "
                  f"(next launch opens it, no rebuild)")

    # queries: perturbed members of known clusters
    qi = rng.choice(args.corpus, args.queries, replace=False)
    q_toks = toks[qi].copy()
    flip = rng.random(q_toks.shape) < 0.1
    q_toks[flip] = rng.integers(0, cfg.vocab, int(flip.sum()))
    q_embs = embed_fn(params, jnp.asarray(q_toks))
    dim = index.n

    if index.device_resident:
        run = lambda: vector.search_vectors(index, q_embs, k=args.k)
    else:
        q_prep = vector.prep_vectors(q_embs)
        run = lambda: storage.ooc_search(index, q_prep, k=args.k,
                                         normalize_queries=False)
    res = run()                                         # warmup + compile
    jax.block_until_ready(res.dist)
    t0 = time.perf_counter()
    res = run()
    jax.block_until_ready(res.dist)
    dt = (time.perf_counter() - t0) / args.queries * 1e3

    ids = np.asarray(res.idx)                           # (Q, K) result lists
    cos = np.asarray(vector.cosine_scores(res, dim=dim))
    valid = ids >= 0                                    # k > corpus -> -1 pads
    hits = (topics[np.where(valid, ids, 0)] == topics[qi][:, None]) & valid
    same_topic = hits.sum() / max(valid.sum(), 1)
    self_hit = np.mean(ids[:, 0] == qi)
    print(f"served {args.queries} queries (top-{args.k}): {dt:.2f} ms/query")
    print(f"  exact self-retrieval@1: {100*self_hit:.0f}%   "
          f"same-topic neighbours@{args.k}: {100*same_topic:.0f}%")
    print(f"  rank-1 cosine {cos[:, 0].mean():.3f}  "
          f"rank-{args.k} cosine {cos[:, -1].mean():.3f}")
    print(f"  refined {float(np.mean(np.asarray(res.stats.series_refined))):.0f} "
          f"of {args.corpus} embeddings per query (pruning at work)")
    if not index.device_resident:
        print(f"  raw bytes read: {res.io.bytes_read:,} of "
              f"{res.io.bytes_scan:,} a scan would need "
              f"({100 * res.io.read_fraction:.0f}%)")


if __name__ == "__main__":
    main()
