"""End-to-end driver (the paper's kind: serving similarity search).

The paper's SV notes the technique "applies to high-dimensional vectors in
general ... such as deep-learning embeddings".  This example is that
application end to end:

  1. embed a corpus of token sequences with a (reduced) assigned LM,
  2. build the MESSI vector index over the embeddings,
  3. serve a LOOP of batched nearest-neighbour query batches (new
     sequences -> embed -> exact cosine top-k result lists), reporting
     p50/p99 per-batch latency — and, out-of-core, the block-cache
     hit-rate of the shared ``storage.SearchSession``.

With ``--index-path`` the index persists across launches (DESIGN.md §5):
the first run builds and saves it; every later run skips the corpus
embedding + build entirely and OPENS the file out-of-core — summaries on
device, raw embeddings streamed from disk per query batch — which is how
a server cold-starts against an index far larger than device memory.

    PYTHONPATH=src python examples/serve_with_index.py [--arch rwkv6-7b] \\
        [--k 5] [--index-path /tmp/corpus.dsix]
"""
import argparse
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import storage
from repro.configs import get_config
from repro.core import vector
from repro.models import common, transformer as T


def embed(params, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean-pooled final hidden state as the sequence embedding."""
    ctx = T.Ctx(cfg, None, (), "train")
    x = T.embed_inputs(params, {"tokens": tokens}, cfg, ctx)
    x, _, _ = T.decoder_stack(params, x, cfg, ctx)
    x = common.rmsnorm(x, params["final_norm"])
    return jnp.mean(x.astype(jnp.float32), axis=1)          # (B, d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--corpus", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k", type=int, default=5,
                    help="neighbours returned per query (exact top-k)")
    ap.add_argument("--batches", type=int, default=8,
                    help="serving loop length: query batches answered "
                         "back to back (out-of-core runs share one "
                         "SearchSession, so later batches hit its cache)")
    ap.add_argument("--cache-blocks", type=int, default=64,
                    help="SearchSession LRU capacity, in raw blocks "
                         "(out-of-core serving only)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="speculative block reads kept in flight ahead "
                         "of the walk (out-of-core only; answers are "
                         "bit-identical at every setting)")
    ap.add_argument("--group-blocks", type=int, default=1,
                    help="surviving blocks batched per refine dispatch, "
                         "one threshold sync per group (out-of-core "
                         "only; answers are bit-identical)")
    ap.add_argument("--readers", type=int, default=2,
                    help="block-cache reader threads (out-of-core only)")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="tenant threads per batch (out-of-core only): "
                         "each thread submit()s its share of the queries "
                         "and blocks on its ticket; one coalesced drain "
                         "answers all of them through the shared cache")
    ap.add_argument("--index-path", default=None,
                    help="persisted index file: built+saved on first run, "
                         "opened out-of-core (no rebuild) afterwards")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = common.build_params(T.param_specs(cfg), key)
    rng = np.random.default_rng(0)

    # corpus: documents from 8 topical clusters (cluster = token offset)
    topics = rng.integers(0, 8, args.corpus)
    toks = ((topics[:, None] * 61 + rng.integers(0, 32,
             (args.corpus, args.seq))) % cfg.vocab).astype(np.int32)
    embed_fn = jax.jit(lambda p, t: embed(p, cfg, t))

    index = None
    if args.index_path and os.path.exists(args.index_path):
        extra = storage.read_meta(args.index_path)["extra"]
        # the embedding space is defined by (model, corpus): a mismatch on
        # either would silently serve neighbours from the wrong space
        want = {"kind": "vector", "corpus": args.corpus, "arch": args.arch}
        if {k: extra.get(k) for k in want} != want:
            raise SystemExit(f"{args.index_path} holds {extra}, not a "
                             f"vector index for {want} — delete it "
                             f"or pass a different --index-path")
        index = storage.open_index(args.index_path)
        print(f"opened {args.index_path} out-of-core: "
              f"{index.n_real} x {index.n} embeddings, "
              f"{index.n_blocks} blocks on disk")
    else:
        print(f"embedding {args.corpus} docs with {cfg.name} (reduced) ...")
        embs = []
        t0 = time.perf_counter()
        for i in range(0, args.corpus, 256):
            embs.append(embed_fn(params, jnp.asarray(toks[i:i + 256])))
        embs = jnp.concatenate(embs)
        jax.block_until_ready(embs)
        print(f"  {time.perf_counter()-t0:.1f}s -> embeddings {embs.shape}")

        if args.index_path:
            # persisted first launch goes through the staged build pipeline
            # (DESIGN.md §5): embeddings land in a SeriesStore next to the
            # index, and the sharded build records every stage in a
            # manifest — a launch killed mid-build resumes from the last
            # completed unit instead of rebuilding (the progress line says
            # so), and the finished file is byte-identical to
            # save_index(core.build(...))
            prepped = np.asarray(vector.prep_vectors(embs, True))
            store = storage.SeriesStore.write(args.index_path + ".series",
                                              prepped)
            print("building MESSI vector index (staged pipeline, "
                  "resumable) ...")
            index = storage.pipeline_build(
                store, args.index_path, w=16, card=256, capacity=256,
                normalize=False, workers=2,
                extra={"kind": "vector", "dim": embs.shape[-1],
                       "corpus": args.corpus, "arch": args.arch},
                progress=lambda m: print(f"  [build] {m}"))
            print(f"published index -> {args.index_path} (opened "
                  f"out-of-core; next launch skips embed+build entirely)")
        else:
            print("building MESSI vector index ...")
            index = vector.build_vector_index(embs, capacity=256)

    # serving traffic: --batches query batches, each perturbed members of
    # known clusters (fresh draws per batch, so only the index blocks their
    # survivors share are re-usable across batches — realistic locality)
    batches = []
    for _ in range(args.batches):
        qi = rng.choice(args.corpus, args.queries, replace=False)
        q_toks = toks[qi].copy()
        flip = rng.random(q_toks.shape) < 0.1
        q_toks[flip] = rng.integers(0, cfg.vocab, int(flip.sum()))
        batches.append((qi, embed_fn(params, jnp.asarray(q_toks))))
    dim = index.n

    session = None
    if index.device_resident:
        run = lambda qe: vector.search_vectors(index, qe, k=args.k)
        jax.block_until_ready(run(batches[0][1]).dist)  # compile warmup
    else:
        # compile warmup on a throwaway session: the jit cache is global
        # but the block cache is per-session, so the measured loop (and
        # its reported hit-rate) starts genuinely cold
        with storage.SearchSession(index, cache_blocks=2) as warmup:
            jax.block_until_ready(
                warmup.search(batches[0][1], k=args.k,
                              metric=vector.Cosine()).dist)
        session = storage.SearchSession(
            index, cache_blocks=args.cache_blocks, readers=args.readers,
            pipeline_depth=args.pipeline_depth,
            group_blocks=args.group_blocks)
        # the engine's Cosine metric owns the unit-norm prep, so the
        # session serves raw embeddings directly (DESIGN.md §4 matrix:
        # Cosine x cached backend)
        if args.concurrency > 1:
            # multi-tenant serving (DESIGN.md §9): split the batch over
            # tenant threads; every thread submits its slice and blocks
            # on its own ticket — the first to ask drains for everyone,
            # and answers are bit-identical to the single-tenant path
            def run(qe):
                n_t = min(args.concurrency, qe.shape[0])
                cuts = np.array_split(np.arange(qe.shape[0]), n_t)
                results = [None] * n_t
                admitted = threading.Barrier(n_t)

                def tenant(i):
                    t = session.submit(qe[cuts[i]], k=args.k,
                                       metric=vector.Cosine())
                    admitted.wait()   # all tenants in before anyone drains
                    results[i] = t.result()

                threads = [threading.Thread(target=tenant, args=(i,))
                           for i in range(n_t)]
                for th in threads:
                    th.start()
                for th in threads:
                    th.join()
                first = results[0]
                return type(first)(
                    dist=jnp.concatenate([r.dist for r in results]),
                    idx=jnp.concatenate([r.idx for r in results]),
                    stats=first.stats, io=first.io)
        else:
            run = lambda qe: session.search(qe, k=args.k,
                                            metric=vector.Cosine())

    lat_ms = []
    for qi, q_embs in batches:                          # the serving loop
        t0 = time.perf_counter()
        res = run(q_embs)
        jax.block_until_ready(res.dist)
        lat_ms.append((time.perf_counter() - t0) * 1e3)
    p50, p99 = np.percentile(lat_ms, [50, 99])

    ids = np.asarray(res.idx)           # quality stats from the last batch
    cos = np.asarray(vector.cosine_scores(res, dim=dim))
    valid = ids >= 0                                    # k > corpus -> -1 pads
    hits = (topics[np.where(valid, ids, 0)] == topics[qi][:, None]) & valid
    same_topic = hits.sum() / max(valid.sum(), 1)
    self_hit = np.mean(ids[:, 0] == qi)
    print(f"served {args.batches} batches x {args.queries} queries "
          f"(top-{args.k}): p50 {p50:.1f} ms/batch  p99 {p99:.1f} ms/batch "
          f"({p50 / args.queries:.2f} ms/query at p50)")
    print(f"  exact self-retrieval@1: {100*self_hit:.0f}%   "
          f"same-topic neighbours@{args.k}: {100*same_topic:.0f}%")
    print(f"  rank-1 cosine {cos[:, 0].mean():.3f}  "
          f"rank-{args.k} cosine {cos[:, -1].mean():.3f}")
    print(f"  refined {float(np.mean(np.asarray(res.stats.series_refined))):.0f} "
          f"of {args.corpus} embeddings per query (pruning at work)")
    if session is not None:
        if args.concurrency > 1:
            print(f"  served by {args.concurrency} tenant threads per "
                  f"batch through one coalesced drain (answers identical "
                  f"to the single-tenant path)")
        print(f"  block cache ({args.cache_blocks} blocks): "
              f"{100 * session.hit_rate:.0f}% hit-rate over the session "
              f"({session.cache_hits} hits / {session.blocks_fetched} "
              f"disk fetches); last batch read {res.io.bytes_read:,} of "
              f"{res.io.bytes_scan:,} scan bytes "
              f"({100 * res.io.read_fraction:.0f}%)")
        session.close()


if __name__ == "__main__":
    main()
