"""Quickstart: build a MESSI index and answer exact 1-NN queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core.ucr import search_scan
from repro.data import random_walk


def main():
    # 100k random-walk series of 256 points (the paper's Synthetic recipe)
    raw = jnp.asarray(random_walk(100_000, 256, seed=0))
    queries = jnp.asarray(random_walk(10, 256, seed=1))

    print("building MESSI block index ...")
    index = core.build(raw, capacity=1024)
    print(f"  {index.n_blocks} blocks x {index.capacity} series")

    print("searching (exact 1-NN) ...")
    res = core.search(index, queries)  # (Q, 1) results; pass k= for more
    for i in range(10):
        print(f"  query {i}: nn={int(res.idx[i, 0]):6d} "
              f"dist={float(res.dist[i, 0]):8.4f} "
              f"refined {int(res.stats.series_refined[i])} / 100000 series")

    # cross-check against the brute-force oracle
    oracle = search_scan(raw, queries)
    assert np.array_equal(np.asarray(res.idx), np.asarray(oracle.idx))
    print("verified: answers identical to the full scan, "
          f"{100_000 / float(np.mean(np.asarray(res.stats.series_refined))):.0f}x "
          "less real-distance work")


if __name__ == "__main__":
    main()
