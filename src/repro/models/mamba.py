"""Mamba-style selective SSM head (the SSM half of Hymba's hybrid layers).

Recurrence (per channel c, state dim N):
    h_t = a_t * h_{t-1} + b_t,   a_t = exp(dt_t * A_c),  b_t = dt_t * B_t * x_t
    y_t = <C_t, h_t> + D_c * x_c

Training/prefill uses a chunked ``lax.scan`` carrying the inter-chunk state
with a ``lax.associative_scan`` inside each chunk (the standard way to get a
parallel linear recurrence in JAX; work O(S log C), depth O(S/C · log C)).
Decode is the one-step recurrence plus a depthwise-conv ring buffer.

``mamba_naive`` is the sequential oracle the chunked form is property-tested
against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class MambaState(NamedTuple):
    h: jax.Array          # (B, d_inner, N) ssm state
    conv: jax.Array       # (B, K-1, d_inner) depthwise conv history


def param_specs(cfg, d_inner: int) -> dict:
    """One stacked Mamba head bank. Logical axes shard d_inner over model."""
    L, d, n, k = cfg.n_layers, cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    S = common.ParamSpec
    return {
        "w_in": S((L, d, 2 * d_inner), ("layers", "embed", "d_inner")),
        "conv": S((L, k, d_inner), ("layers", None, "d_inner"), scale=0.5),
        "w_dt": S((L, d_inner, 1), ("layers", "d_inner", None), scale=0.5),
        "dt_bias": S((L, d_inner), ("layers", "d_inner"), init="zeros"),
        "w_b": S((L, d_inner, n), ("layers", "d_inner", None), scale=0.5),
        "w_c": S((L, d_inner, n), ("layers", "d_inner", None), scale=0.5),
        "a_log": S((L, d_inner, n), ("layers", "d_inner", None),
                   init="value", value=0.0),
        "d_skip": S((L, d_inner), ("layers", "d_inner"), init="ones"),
        "w_out": S((L, d_inner, d), ("layers", "d_inner", "embed_out")),
    }


def _conv_causal(x: jax.Array, kernel: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x (B, S, D); kernel (K, D); history (B, K-1, D)."""
    k = kernel.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)                  # (B, S+K-1, D)
    out = sum(xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
              for i in range(k))
    return out


def _ssm_coeffs(xc: jax.Array, p: dict):
    """xc (B, S, D) conv output -> (a, b, c_t) for the linear recurrence."""
    dt = jax.nn.softplus(xc * p["w_dt"][..., 0] + p["dt_bias"])    # (B,S,D)
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))               # (D, N)
    bt = jnp.einsum("bsd,dn->bsn", xc, p["w_b"])                   # (B,S,N)
    ct = jnp.einsum("bsd,dn->bsn", xc, p["w_c"])                   # (B,S,N)
    a = jnp.exp(dt[..., None] * a_mat[None, None])                 # (B,S,D,N)
    b = (dt * xc)[..., None] * bt[:, :, None, :]                   # (B,S,D,N)
    return a.astype(jnp.float32), b.astype(jnp.float32), ct


def _chunk_scan(a, b, h0):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis 1, init h0.

    a, b (B, C, D, N); h0 (B, D, N). Returns (h (B, C, D, N), h_last)."""
    # fold h0 into the first step, then associative scan
    b = b.at[:, 0].add(a[:, 0] * h0)
    op = lambda p, q: (q[0] * p[0], q[0] * p[1] + q[1])
    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h, h[:, -1]


def mamba_mix(x: jax.Array, p: dict, *, d_inner: int, chunk: int = 256,
              state: MambaState | None = None
              ) -> tuple[jax.Array, MambaState]:
    """Full Mamba mixer. x (B, S, d_model) -> (B, S, d_model), final state."""
    b, s, _ = x.shape
    k = p["conv"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = xz[..., :d_inner], xz[..., d_inner:]
    hist = (state.conv if state is not None
            else jnp.zeros((b, k - 1, d_inner), x.dtype))
    xc = jax.nn.silu(_conv_causal(xi, p["conv"], hist))
    a, bb, ct = _ssm_coeffs(xc, p)

    h0 = (state.h if state is not None
          else jnp.zeros((b, d_inner, p["w_b"].shape[1]), jnp.float32))
    c = min(chunk, s)
    if s % c:
        c = s                                       # odd lengths: one chunk
    nc = s // c

    def step(h, inp):
        ac, bc = inp                                # (B, C, D, N)
        hs, hl = _chunk_scan(ac, bc, h)
        return hl, hs

    a_c = a.reshape(b, nc, c, d_inner, -1).swapaxes(0, 1)
    b_c = bb.reshape(b, nc, c, d_inner, -1).swapaxes(0, 1)
    h_last, hs = jax.lax.scan(step, h0, (a_c, b_c))
    h_all = hs.swapaxes(0, 1).reshape(b, s, d_inner, -1)        # (B,S,D,N)

    y = jnp.einsum("bsdn,bsn->bsd", h_all, ct.astype(jnp.float32))
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])

    tail = jnp.concatenate([hist, xi], axis=1)[:, -(k - 1):]
    return out, MambaState(h=h_last, conv=tail)


def mamba_naive(x: jax.Array, p: dict, *, d_inner: int,
                state: MambaState | None = None
                ) -> tuple[jax.Array, MambaState]:
    """Sequential oracle: same math, plain per-step scan."""
    b, s, _ = x.shape
    k = p["conv"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = xz[..., :d_inner], xz[..., d_inner:]
    hist = (state.conv if state is not None
            else jnp.zeros((b, k - 1, d_inner), x.dtype))
    xc = jax.nn.silu(_conv_causal(xi, p["conv"], hist))
    a, bb, ct = _ssm_coeffs(xc, p)
    h0 = (state.h if state is not None
          else jnp.zeros((b, d_inner, p["w_b"].shape[1]), jnp.float32))

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(step, h0,
                              (a.swapaxes(0, 1), bb.swapaxes(0, 1)))
    h_all = hs.swapaxes(0, 1)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, ct.astype(jnp.float32))
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    tail = jnp.concatenate([hist, xi], axis=1)[:, -(k - 1):]
    return out, MambaState(h=h_last, conv=tail)


def init_state(batch: int, d_inner: int, n_state: int, k_conv: int,
               dtype=jnp.bfloat16) -> MambaState:
    return MambaState(h=jnp.zeros((batch, d_inner, n_state), jnp.float32),
                      conv=jnp.zeros((batch, k_conv - 1, d_inner), dtype))
