"""Unified LM stack for all 10 assigned architectures.

One parameter layout + three entry points (`forward` / `prefill` /
`decode_step`) cover the dense / moe / vlm / hybrid / ssm decoder families;
`audio` (Whisper) adds an encoder stack and cross-attention.

Heterogeneous layer patterns (gemma3's 5:1 local:global, hymba's explicit
global set) are handled by *segments*: params are stacked over all layers,
the static layer-kind list is cut into runs of identical kind, each run is
sliced out and scanned with ``lax.scan`` + ``jax.checkpoint`` — HLO size is
O(#segments), compute identical to a per-layer loop.

Caches are per-segment pytrees: full-attention segments carry (run, B, S,
KVH, hd) K/V; SWA segments carry ring buffers of width ``window``; hybrid
segments add Mamba states; ssm segments carry RWKV states.  ``long_500k``
full-attention caches (gemma3's global layers) use the sequence-sharded
flash-decode path in attention.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, common, mamba, moe, rwkv
from repro.models.common import ParamSpec as PS


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # "full" | "swa" (attention flavour of the run)
    start: int
    end: int           # exclusive

    @property
    def size(self) -> int:
        return self.end - self.start


def segments(cfg: ModelConfig, n_layers: int | None = None) -> list[Segment]:
    n = cfg.n_layers if n_layers is None else n_layers
    kinds = [cfg.layer_kind(i) for i in range(n)]
    segs, a = [], 0
    for i in range(1, n + 1):
        if i == n or kinds[i] != kinds[a]:
            segs.append(Segment(kinds[a], a, i))
            a = i
    return segs


def _chunk_for(seq: int, want: int) -> int:
    """Largest divisor of ``seq`` that is <= want (chunked attn needs S % C == 0)."""
    c = min(want, seq)
    while seq % c:
        c -= 1
    return c


def _slice_seg(tree, seg: Segment):
    return jax.tree.map(
        lambda a: jax.lax.slice_in_dim(a, seg.start, seg.end, axis=0), tree)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, L: int) -> dict:
    d, q, kv, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    s = {
        "wq": PS((L, d, q), ("layers", "embed", "q_heads")),
        "wk": PS((L, d, kv), ("layers", "embed", "kv_fused")),
        "wv": PS((L, d, kv), ("layers", "embed", "kv_fused")),
        "wo": PS((L, q, d), ("layers", "q_heads", "embed_out")),
    }
    if cfg.qk_norm:
        s["q_gamma"] = PS((L, hd), ("layers", None), init="zeros")
        s["k_gamma"] = PS((L, hd), ("layers", None), init="zeros")
    return s


def _ffn_specs(cfg: ModelConfig, L: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    s = {
        "wu": PS((L, d, f), ("layers", "ff_in", "ff")),
        "wd": PS((L, f, d), ("layers", "ff", "embed_out")),
    }
    if cfg.mlp_gated:
        s["wg"] = PS((L, d, f), ("layers", "ff_in", "ff"))
    return s


def param_specs(cfg: ModelConfig) -> dict:
    d, v, L = cfg.d_model, cfg.vocab_padded, cfg.n_layers
    specs: dict[str, Any] = {
        "embed": PS((v, d), ("vocab", "embed"), scale=1.0),
        "final_norm": PS((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = PS((d, v), ("embed", "vocab"))

    if cfg.family == "ssm":
        specs["layers"] = rwkv.param_specs(cfg)
        return specs

    layers: dict[str, Any] = {
        "ln1": PS((L, d), ("layers", "embed"), init="zeros"),
        "ln2": PS((L, d), ("layers", "embed"), init="zeros"),
        "attn": _attn_specs(cfg, L),
    }
    if cfg.family == "hybrid":
        layers["mamba"] = mamba.param_specs(cfg, d_inner=cfg.q_dim)
        layers["attn_gamma"] = PS((L, cfg.q_dim), ("layers", "q_heads"),
                                  init="zeros")
        layers["mamba_gamma"] = PS((L, cfg.q_dim), ("layers", "q_heads"),
                                   init="zeros")
    if cfg.n_experts:
        layers["moe"] = moe.param_specs(cfg)
    else:
        layers["ffn"] = _ffn_specs(cfg, L)
    specs["layers"] = layers

    if cfg.meta_tokens:
        specs["meta"] = PS((cfg.meta_tokens, d), (None, "embed"), scale=1.0)
    if cfg.enc_dec:
        Ld = cfg.n_dec_layers
        specs["enc_final_norm"] = PS((d,), ("embed",), init="zeros")
        specs["dec_pos"] = PS((cfg.decoder_len, d), (None, "embed"), scale=1.0)
        specs["dec"] = {
            "ln1": PS((Ld, d), ("layers", "embed"), init="zeros"),
            "ln_x": PS((Ld, d), ("layers", "embed"), init="zeros"),
            "ln2": PS((Ld, d), ("layers", "embed"), init="zeros"),
            "attn": _attn_specs(cfg, Ld),
            "xattn": _attn_specs(cfg, Ld),
            "ffn": _ffn_specs(cfg, Ld),
        }
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


class Ctx(NamedTuple):
    """Static per-call context threaded through the stack."""
    cfg: ModelConfig
    mesh: Mesh | None
    data_axes: tuple[str, ...]
    mode: str                      # "train" | "prefill" | "decode"
    kv_shard: tuple | None = None  # axes the full-attn KV cache's SEQUENCE
                                   # is sharded over (flash-decode merge)


def _shard_bsd(x: jax.Array, ctx: Ctx) -> jax.Array:
    """Constrain (B, S, d) activations: batch over the data axes."""
    if ctx.mesh is None or not ctx.data_axes:
        return x
    import math
    if x.shape[0] % math.prod(ctx.mesh.shape[a] for a in ctx.data_axes):
        return x
    dp = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(dp, *([None] * (x.ndim - 1)))))


def _qkv(x, p, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = common.rmsnorm(q, p["q_gamma"])
        k = common.rmsnorm(k, p["k_gamma"])
    if positions is not None:                      # rope (not for whisper)
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(x, p, cfg: ModelConfig, kind: str, ctx: Ctx, *,
               causal: bool = True, kv_override=None,
               triangular: bool = False):
    """Full-sequence attention (training / prefill compute).

    Returns (out, (k, v)) so prefill can write the cache."""
    b, s, _ = x.shape
    positions = None if cfg.enc_dec else jnp.arange(s)[None, :]
    q, k, v = _qkv(x, p, cfg, positions)
    if kv_override is not None:                     # cross-attention
        k, v = kv_override
    window = cfg.window if kind == "swa" else 0
    out = attention.attend(
        q, k, v, causal=causal, window=window,
        chunk=_chunk_for(s, cfg.scan_chunk), triangular=triangular)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"], (k, v)


def attn_decode(x, p, cfg: ModelConfig, kind: str, ctx: Ctx, cache, pos):
    """One-token attention against the cache; returns (out, new_cache)."""
    b = x.shape[0]
    q, k, v = _qkv(x, p, cfg, None if cfg.enc_dec else
                   jnp.broadcast_to(jnp.asarray(pos), (b,))[:, None])
    window = cfg.window if kind == "swa" else 0
    if ctx.kv_shard and not window and ctx.mesh is not None:
        # seq-sharded cache: write + flash-decode inside one shard_map
        import math
        nd = math.prod(ctx.mesh.shape[a] for a in ctx.data_axes) \
            if ctx.data_axes else 1
        b_axes = ctx.data_axes if ("model" in ctx.kv_shard
                                   and b % max(nd, 1) == 0) else ()
        out, kc, vc = attention.decode_attend_seqsharded(
            q, k, v, cache["k"], cache["v"], pos, mesh=ctx.mesh,
            axes=ctx.kv_shard, b_axes=b_axes)
    else:
        kc, vc = attention.cache_update(cache["k"], cache["v"],
                                        k.astype(cache["k"].dtype),
                                        v.astype(cache["v"].dtype), pos,
                                        window=window)
        out = attention.decode_attend(q, kc, vc, pos, window=window)
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"], {"k": kc, "v": vc}


def ffn_block(x, p, cfg: ModelConfig, ctx: Ctx):
    act = common.activation(cfg.mlp_act)
    if cfg.n_experts:
        return moe.moe_ffn(x, p, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor, act=act,
                           mesh=ctx.mesh, data_axes=ctx.data_axes)
    if cfg.mlp_gated:
        h = act(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = act(x @ p["wu"])
    return h @ p["wd"], moe.MoEAux(*(jnp.zeros(()) for _ in range(3)))


def _zero_aux():
    return moe.MoEAux(*(jnp.zeros(()) for _ in range(3)))


def _add_aux(a: moe.MoEAux, b: moe.MoEAux) -> moe.MoEAux:
    return moe.MoEAux(a.load_balance + b.load_balance,
                      a.router_z + b.router_z,
                      a.dropped_frac + b.dropped_frac)


# ---------------------------------------------------------------------------
# Decoder layers (train / prefill / decode)
# ---------------------------------------------------------------------------


def layer_train(x, p, cfg: ModelConfig, kind: str, ctx: Ctx,
                triangular: bool = False):
    """One decoder layer, full sequence. Returns (x, aux, (k, v))."""
    h = common.rmsnorm(x, p["ln1"])
    attn_out, kv = attn_train(h, p["attn"], cfg, kind, ctx,
                              triangular=triangular)
    if cfg.family == "hybrid":
        m_out, _ = mamba.mamba_mix(h, p["mamba"], d_inner=cfg.q_dim,
                                   chunk=cfg.scan_chunk)
        mixed = 0.5 * (common.rmsnorm(attn_out, p["attn_gamma"])
                       + common.rmsnorm(m_out, p["mamba_gamma"]))
        attn_out = mixed
    if cfg.parallel_block:
        f_out, aux = ffn_block(h, p.get("moe", p.get("ffn")), cfg, ctx)
        return _shard_bsd(x + attn_out + f_out, ctx), aux, kv
    x = x + attn_out
    f_out, aux = ffn_block(common.rmsnorm(x, p["ln2"]),
                           p.get("moe", p.get("ffn")), cfg, ctx)
    return _shard_bsd(x + f_out, ctx), aux, kv


def layer_decode(x, p, cfg: ModelConfig, kind: str, ctx: Ctx, cache, pos):
    """One decoder layer, one token. Returns (x, new_cache)."""
    h = common.rmsnorm(x, p["ln1"])
    attn_out, new_attn = attn_decode(h, p["attn"], cfg, kind, ctx,
                                     cache, pos)
    new_cache = dict(new_attn)
    if cfg.family == "hybrid":
        mst = mamba.MambaState(h=cache["m_h"], conv=cache["m_conv"])
        m_out, mst = mamba.mamba_mix(h, p["mamba"], d_inner=cfg.q_dim,
                                     chunk=1, state=mst)
        attn_out = 0.5 * (common.rmsnorm(attn_out, p["attn_gamma"])
                          + common.rmsnorm(m_out, p["mamba_gamma"]))
        new_cache.update(m_h=mst.h, m_conv=mst.conv)
    if cfg.parallel_block:
        f_out, _ = ffn_block(h, p.get("moe", p.get("ffn")), cfg, ctx)
        return x + attn_out + f_out, new_cache
    x = x + attn_out
    f_out, _ = ffn_block(common.rmsnorm(x, p["ln2"]),
                         p.get("moe", p.get("ffn")), cfg, ctx)
    return x + f_out, new_cache


def layer_prefill(x, p, cfg: ModelConfig, kind: str, ctx: Ctx, cache):
    """Full-sequence compute + cache population. Returns (x, new_cache)."""
    h = common.rmsnorm(x, p["ln1"])
    attn_out, (k, v) = attn_train(h, p["attn"], cfg, kind, ctx)
    s = x.shape[1]
    window = cfg.window if kind == "swa" else 0
    new_cache = dict(cache)
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if window and s >= window:
        r = s % window
        new_cache["k"] = jnp.roll(kd[:, -window:], r, axis=1)
        new_cache["v"] = jnp.roll(vd[:, -window:], r, axis=1)
    else:
        upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, 0, axis=1)
        new_cache["k"] = upd(cache["k"], kd)
        new_cache["v"] = upd(cache["v"], vd)
    if cfg.family == "hybrid":
        m_out, mst = mamba.mamba_mix(h, p["mamba"], d_inner=cfg.q_dim,
                                     chunk=cfg.scan_chunk)
        attn_out = 0.5 * (common.rmsnorm(attn_out, p["attn_gamma"])
                          + common.rmsnorm(m_out, p["mamba_gamma"]))
        new_cache.update(m_h=mst.h, m_conv=mst.conv)
    if cfg.parallel_block:
        f_out, _ = ffn_block(h, p.get("moe", p.get("ffn")), cfg, ctx)
        return _shard_bsd(x + attn_out + f_out, ctx), new_cache
    x = x + attn_out
    f_out, _ = ffn_block(common.rmsnorm(x, p["ln2"]),
                         p.get("moe", p.get("ffn")), cfg, ctx)
    return _shard_bsd(x + f_out, ctx), new_cache


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _scan_segment(body, x, p_seg, cache_seg=None):
    """Scan ``body`` over the layers of one segment.

    body(x, p_l, cache_l) -> (x, aux, new_cache_l);
    returns (x, aux_sum, new_cache_seg)."""

    def step(carry, xs):
        x, aux = carry
        p_l, c_l = xs
        x, a, new_c = body(x, p_l, c_l)
        return (x, _add_aux(aux, a)), new_c

    (x, aux), new_cache = jax.lax.scan(
        step, (x, _zero_aux()), (p_seg, cache_seg))
    return x, aux, new_cache


def decoder_stack(params, x, cfg: ModelConfig, ctx: Ctx, *,
                  cache=None, pos=None, triangular: bool = False):
    """Run all decoder layers. Returns (x, aux, new_cache)."""
    if cfg.family == "ssm":
        return _rwkv_stack(params, x, cfg, ctx, cache=cache)

    segs = segments(cfg)
    new_cache = []
    aux_t = _zero_aux()
    for si, seg in enumerate(segs):
        p_seg = _slice_seg(params["layers"], seg)
        c_seg = cache[si] if cache is not None else None
        if ctx.mode == "train":
            def body(x, p_l, c_l, _k=seg.kind):
                x, a, _ = layer_train(x, p_l, cfg, _k, ctx,
                                      triangular=triangular)
                return x, a, 0
            body = _remat(body, cfg.remat)
            x, aux, _ = _scan_segment(
                body, x, p_seg,
                jnp.zeros((seg.size,), jnp.int32))
            new_cache.append(None)
        elif ctx.mode == "prefill":
            def body(x, p_l, c_l, _k=seg.kind):
                x, c = layer_prefill(x, p_l, cfg, _k, ctx, c_l)
                return x, _zero_aux(), c
            body = _remat(body, cfg.remat)
            x, aux, c_new = _scan_segment(body, x, p_seg, c_seg)
            new_cache.append(c_new)
        else:
            def body(x, p_l, c_l, _k=seg.kind):
                x, c = layer_decode(x, p_l, cfg, _k, ctx, c_l, pos)
                return x, _zero_aux(), c
            x, aux, c_new = _scan_segment(body, x, p_seg, c_seg)
            new_cache.append(c_new)
        aux_t = _add_aux(aux_t, aux)
    return x, aux_t, new_cache


def _rwkv_stack(params, x, cfg: ModelConfig, ctx: Ctx, *, cache=None):
    p_all = params["layers"]

    def body(carry, xs):
        x = carry
        p_l, st_l = xs
        state = (rwkv.RwkvState(**st_l) if st_l is not None else None)
        x, new_state = rwkv.rwkv_layer(
            x, p_l, head_dim=cfg.rwkv_head_dim,
            chunk=min(64, cfg.scan_chunk), state=state)
        return x, dict(s=new_state.s, x_tm=new_state.x_tm,
                       x_cm=new_state.x_cm)

    if cache is None:
        b = x.shape[0]
        h = cfg.d_model // cfg.rwkv_head_dim
        n = cfg.rwkv_head_dim
        cache0 = dict(
            s=jnp.zeros((cfg.n_layers, b, h, n, n), jnp.float32),
            x_tm=jnp.zeros((cfg.n_layers, b, cfg.d_model), x.dtype),
            x_cm=jnp.zeros((cfg.n_layers, b, cfg.d_model), x.dtype))
    else:
        cache0 = cache[0]

    wrapped = _remat(lambda x, p_l, st_l: (body(x, (p_l, st_l))), cfg.remat) \
        if ctx.mode == "train" else (lambda x, p_l, st_l: body(x, (p_l, st_l)))

    def step(x, xs):
        p_l, st_l = xs
        return wrapped(x, p_l, st_l)

    x, new_states = jax.lax.scan(step, x, (p_all, cache0))
    return x, _zero_aux(), [new_states]


# ---------------------------------------------------------------------------
# Whisper encoder-decoder
# ---------------------------------------------------------------------------


def _sinusoid(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def encoder_stack(params, frames, cfg: ModelConfig, ctx: Ctx):
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model
                           ).astype(frames.dtype)[None]
    x = _shard_bsd(x, ctx)

    def body(x, p_l, _c):
        h = common.rmsnorm(x, p_l["ln1"])
        out, _ = attn_train(h, p_l["attn"], cfg, "full", ctx, causal=False)
        x = x + out
        h = common.rmsnorm(x, p_l["ln2"])
        f_out, _ = ffn_block(h, p_l["ffn"], cfg, ctx)
        return _shard_bsd(x + f_out, ctx), _zero_aux(), 0

    seg = Segment("full", 0, cfg.n_layers)
    x, _, _ = _scan_segment(_remat(body, cfg.remat), x,
                            _slice_seg(params["layers"], seg),
                            jnp.zeros((cfg.n_layers,), jnp.int32))
    return common.rmsnorm(x, params["enc_final_norm"])


def whisper_decoder(params, tokens, enc_out, cfg: ModelConfig, ctx: Ctx, *,
                    cache=None, pos=None):
    """Decoder with self- + cross-attention.

    Train/prefill: tokens (B, T).  Decode: tokens (B, 1) at ``pos`` with
    cache = {"k","v" (self), "xk","xv" (cross, precomputed at prefill)}."""
    x = params["embed"][tokens]
    if ctx.mode != "decode":
        x = x + params["dec_pos"][None, :x.shape[1]].astype(x.dtype)
    else:
        x = x + params["dec_pos"][pos][None, None].astype(x.dtype)

    def body_full(x, p_l, c_l):
        h = common.rmsnorm(x, p_l["ln1"])
        out, (k, v) = attn_train(h, p_l["attn"], cfg, "full", ctx)
        x = x + out
        h = common.rmsnorm(x, p_l["ln_x"])
        bq, sq, _ = h.shape
        q = (h @ p_l["xattn"]["wq"]).reshape(bq, sq, cfg.n_heads,
                                             cfg.head_dim)
        xkv_k = (enc_out @ p_l["xattn"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        xkv_v = (enc_out @ p_l["xattn"]["wv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        out = attention.attend(q, xkv_k, xkv_v, causal=False,
                               chunk=_chunk_for(x.shape[1], cfg.scan_chunk))
        x = x + out.reshape(x.shape[0], x.shape[1], cfg.q_dim) \
            @ p_l["xattn"]["wo"]
        h = common.rmsnorm(x, p_l["ln2"])
        f_out, _ = ffn_block(h, p_l["ffn"], cfg, ctx)
        new_c = 0
        if ctx.mode == "prefill":
            t = k.shape[1]
            new_c = dict(
                k=jax.lax.dynamic_update_slice_in_dim(c_l["k"], k, 0, 1),
                v=jax.lax.dynamic_update_slice_in_dim(c_l["v"], v, 0, 1),
                xk=xkv_k, xv=xkv_v)
        return _shard_bsd(x + f_out, ctx), _zero_aux(), new_c

    def body_decode(x, p_l, c_l):
        b = x.shape[0]
        h = common.rmsnorm(x, p_l["ln1"])
        q, k, v = _qkv(h, p_l["attn"], cfg, None)
        kc, vc = attention.cache_update(c_l["k"], c_l["v"], k, v, pos)
        out = attention.decode_attend(q, kc, vc, pos)
        x = x + out.reshape(b, 1, cfg.q_dim) @ p_l["attn"]["wo"]
        h = common.rmsnorm(x, p_l["ln_x"])
        q, _, _ = _qkv(h, p_l["xattn"], cfg, None)
        big = c_l["xk"].shape[1]
        out = attention.decode_attend(q, c_l["xk"], c_l["xv"],
                                      jnp.asarray(big - 1))
        x = x + out.reshape(b, 1, cfg.q_dim) @ p_l["xattn"]["wo"]
        h = common.rmsnorm(x, p_l["ln2"])
        f_out, _ = ffn_block(h, p_l["ffn"], cfg, ctx)
        return x + f_out, _zero_aux(), dict(k=kc, v=vc, xk=c_l["xk"],
                                            xv=c_l["xv"])

    seg = Segment("full", 0, cfg.n_dec_layers)
    p_seg = _slice_seg(params["dec"], seg)
    if ctx.mode == "decode":
        x, _, new_cache = _scan_segment(body_decode, x, p_seg, cache[0])
    elif ctx.mode == "prefill":
        x, _, new_cache = _scan_segment(body_full, x, p_seg, cache[0])
    else:
        body = _remat(body_full, cfg.remat)
        x, _, _ = _scan_segment(body, x, p_seg,
                                jnp.zeros((cfg.n_dec_layers,), jnp.int32))
        new_cache = None
    x = common.rmsnorm(x, params["final_norm"])
    return x, ([new_cache] if new_cache is not None else None)


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


def embed_inputs(params, batch: dict, cfg: ModelConfig, ctx: Ctx):
    """Token (+ modality prefix / meta token) embedding. -> (B, S_total, d)."""
    x = params["embed"][batch["tokens"]]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None].astype(x.dtype),
                                (x.shape[0], cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
    return _shard_bsd(x, ctx)


def lm_logits(params, x, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = common.softcap(logits, cfg.logit_softcap)
    if cfg.vocab_padded != cfg.vocab:           # drop padded columns
        logits = logits[..., :cfg.vocab]
    return logits


def forward(params, batch: dict, cfg: ModelConfig, *, mesh: Mesh | None = None,
            data_axes: tuple[str, ...] = (), triangular: bool = False):
    """Teacher-forcing forward -> (logits (B, S, V), aux)."""
    ctx = Ctx(cfg, mesh, data_axes, "train")
    if cfg.enc_dec:
        enc = encoder_stack(params, batch["frames"], cfg, ctx)
        x, _ = whisper_decoder(params, batch["dec_tokens"], enc, cfg, ctx)
        return lm_logits(params, x, cfg), _zero_aux()
    x = embed_inputs(params, batch, cfg, ctx)
    x, aux, _ = decoder_stack(params, x, cfg, ctx, triangular=triangular)
    x = common.rmsnorm(x, params["final_norm"])
    prefix = cfg.meta_tokens + (batch.get("patches").shape[1]
                                if cfg.family == "vlm"
                                and batch.get("patches") is not None else 0)
    if prefix:
        x = x[:, prefix:]
    return lm_logits(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig, *,
            mesh: Mesh | None = None, data_axes: tuple[str, ...] = (),
            vocab_chunk: int = 0, triangular: bool = False):
    """Next-token CE loss with chunked logits (never materializes (B,S,V)).

    labels = tokens shifted left; positions with label < 0 are masked.
    Returns (loss, metrics dict)."""
    ctx = Ctx(cfg, mesh, data_axes, "train")
    if cfg.enc_dec:
        enc = encoder_stack(params, batch["frames"], cfg, ctx)
        x, _ = whisper_decoder(params, batch["dec_tokens"], enc, cfg, ctx)
        tokens = batch["dec_tokens"]
        aux = _zero_aux()
    else:
        x = embed_inputs(params, batch, cfg, ctx)
        x, aux, _ = decoder_stack(params, x, cfg, ctx, triangular=triangular)
        x = common.rmsnorm(x, params["final_norm"])
        prefix = cfg.meta_tokens + (batch.get("patches").shape[1]
                                    if cfg.family == "vlm"
                                    and batch.get("patches") is not None else 0)
        if prefix:
            x = x[:, prefix:]
        tokens = batch["tokens"]

    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    b, s, d = x.shape
    chunk = _chunk_for(s, vocab_chunk or min(512, s))
    pad_mask = None
    if cfg.vocab_padded != cfg.vocab:           # keep the padded shape
        pad_mask = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab,
                             0.0, -1e30).astype(jnp.float32)

    def ce_chunk(carry, i):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = common.softcap(
            xs.astype(jnp.float32) @ head.astype(jnp.float32),
            cfg.logit_softcap)
        if pad_mask is not None:
            logits = logits + pad_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - gold) * mask), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(s // chunk))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce
    if cfg.n_experts:
        loss = loss + 0.01 * aux.load_balance / cfg.n_layers \
            + 1e-4 * aux.router_z / cfg.n_layers
    metrics = {"ce": ce, "loss": loss, "tokens": cnt,
               "moe_lb": aux.load_balance, "moe_drop": aux.dropped_frac}
    return loss, metrics


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> list:
    """Per-segment cache pytree (zeros); shapes depend on segment kinds."""
    if cfg.enc_dec:
        Ld = cfg.n_dec_layers
        return [dict(
            k=jnp.zeros((Ld, batch, cfg.decoder_len, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
            v=jnp.zeros((Ld, batch, cfg.decoder_len, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
            xk=jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads,
                          cfg.head_dim), dtype),
            xv=jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads,
                          cfg.head_dim), dtype))]
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        n = cfg.rwkv_head_dim
        L = cfg.n_layers
        return [dict(s=jnp.zeros((L, batch, h, n, n), jnp.float32),
                     x_tm=jnp.zeros((L, batch, cfg.d_model), dtype),
                     x_cm=jnp.zeros((L, batch, cfg.d_model), dtype))]
    total = max_len + cfg.meta_tokens
    out = []
    for seg in segments(cfg):
        s_kv = min(cfg.window, total) if seg.kind == "swa" else total
        c = dict(k=jnp.zeros((seg.size, batch, s_kv, cfg.n_kv_heads,
                              cfg.head_dim), dtype),
                 v=jnp.zeros((seg.size, batch, s_kv, cfg.n_kv_heads,
                              cfg.head_dim), dtype))
        if cfg.family == "hybrid":
            c.update(m_h=jnp.zeros((seg.size, batch, cfg.q_dim,
                                    cfg.ssm_state), jnp.float32),
                     m_conv=jnp.zeros((seg.size, batch, cfg.ssm_conv - 1,
                                       cfg.q_dim), dtype))
        out.append(c)
    return out


def prefill(params, batch: dict, cache: list, cfg: ModelConfig, *,
            mesh: Mesh | None = None, data_axes: tuple[str, ...] = ()):
    """Process the prompt; returns (last-position logits, filled cache)."""
    ctx = Ctx(cfg, mesh, data_axes, "prefill")
    if cfg.enc_dec:
        enc = encoder_stack(params, batch["frames"], cfg, ctx)
        x, new_cache = whisper_decoder(params, batch["dec_tokens"], enc,
                                       cfg, ctx, cache=cache)
        return lm_logits(params, x[:, -1:], cfg), new_cache
    x = embed_inputs(params, batch, cfg, ctx)
    x, _, new_cache = decoder_stack(params, x, cfg, ctx, cache=cache)
    x = common.rmsnorm(x, params["final_norm"])
    return lm_logits(params, x[:, -1:], cfg), new_cache


def decode_step(params, tokens, pos, cache: list, cfg: ModelConfig, *,
                mesh: Mesh | None = None, data_axes: tuple[str, ...] = (),
                kv_shard: tuple | None = None):
    """One token step. tokens (B, 1); pos = its absolute position (scalar).

    Returns (logits (B, 1, V), new_cache)."""
    ctx = Ctx(cfg, mesh, data_axes, "decode", kv_shard=kv_shard)
    if cfg.enc_dec:
        x, new_cache = whisper_decoder(params, tokens, None, cfg, ctx,
                                       cache=cache, pos=pos)
        return lm_logits(params, x, cfg), new_cache
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    eff_pos = pos + cfg.meta_tokens if cfg.meta_tokens else pos
    x, _, new_cache = decoder_stack(params, x, cfg, ctx, cache=cache,
                                    pos=eff_pos)
    x = common.rmsnorm(x, params["final_norm"])
    return lm_logits(params, x, cfg), new_cache
