"""Token-choice top-k Mixture-of-Experts with sort-based dispatch.

Two execution paths sharing the same math:

  * local (mesh=None)  — every device sees all experts; used by the CPU
    smoke tests and as the reference the EP path is validated against;
  * EP (mesh given)    — experts sharded over the "model" axis inside a
    manual ``shard_map``: each device routes its local tokens, packs a
    fixed-capacity per-expert buffer, exchanges it with one
    ``all_to_all`` (the GShard dispatch), runs its local experts, and
    reverses the exchange for the combine.  No one-hot dispatch einsums —
    dispatch is a sort + scatter, so HLO FLOPs stay ~= the useful expert
    FLOPs (this is what keeps MODEL_FLOPS/HLO_FLOPs honest in §Roofline).

Capacity: per (source device, expert) C = ceil(T*k/E * cf) rounded up to a
multiple of 8; overflowing assignments are dropped (token keeps its other
experts' contributions — standard dropping semantics), counted in aux stats.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.models import common


class MoEAux(NamedTuple):
    load_balance: jax.Array     # Switch-style aux loss (scalar)
    router_z: jax.Array         # router z-loss (scalar)
    dropped_frac: jax.Array     # fraction of assignments dropped (scalar)


def capacity(n_tokens: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(-(-n_tokens * top_k * cf // n_experts))   # ceil
    return max(8, -(-c // 8) * 8)                     # round up to 8


def route(x: jax.Array, w_router: jax.Array, top_k: int
          ) -> tuple[jax.Array, jax.Array, MoEAux]:
    """x (T, d) -> (weights (T, K), expert ids (T, K), aux losses)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    w, ids = jax.lax.top_k(probs, top_k)                       # (T, K)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    e = probs.shape[-1]
    # Switch load-balance loss: E * sum_e f_e * p_e
    sel = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)      # top-1 choice
    lb = e * jnp.sum(jnp.mean(sel, axis=0) * jnp.mean(probs, axis=0))
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return w, ids, MoEAux(lb, z, jnp.zeros((), jnp.float32))


def _dispatch_indices(ids: jax.Array, n_experts: int, cap: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based slot assignment.

    ids (T, K) -> (token_of_assignment (A,), slot (A,), kept (A,)) where
    ``slot`` indexes a (E*cap) buffer (== E*cap means dropped) and A = T*K.
    Assignments are ranked within their expert by (token, k) order — the
    deterministic analogue of the paper's Fetch&Inc work claiming.
    """
    t, k = ids.shape
    a = t * k
    eids = ids.reshape(a)
    tok = jnp.arange(a, dtype=jnp.int32) // k
    order = jnp.argsort(eids, stable=True)                     # group by expert
    es = eids[order]
    # rank within expert group = position - group start
    counts = jnp.bincount(eids, length=n_experts)              # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(a, dtype=jnp.int32) - starts[es].astype(jnp.int32)
    pos = jnp.zeros((a,), jnp.int32).at[order].set(pos_sorted)  # unsort
    kept = pos < cap
    slot = jnp.where(kept, eids * cap + pos, n_experts * cap)
    return tok, slot, kept


def _expert_ffn(buf: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                act) -> jax.Array:
    """buf (E, C, d); wg/wu (E, d, f); wd (E, f, d) -> (E, C, d)."""
    h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) \
        * jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_ffn_local(x: jax.Array, params: dict, *, top_k: int,
                  capacity_factor: float, act) -> tuple[jax.Array, MoEAux]:
    """All experts local.  x (T, d) -> (T, d)."""
    t, d = x.shape
    e = params["wg"].shape[0]
    cap = capacity(t, e, top_k, capacity_factor)
    w, ids, aux = route(x, params["router"], top_k)
    tok, slot, kept = _dispatch_indices(ids, e, cap)

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(x[tok])
    out_e = _expert_ffn(buf[:-1].reshape(e, cap, d),
                        params["wg"], params["wu"], params["wd"], act)
    out_e = jnp.concatenate([out_e.reshape(e * cap, d),
                             jnp.zeros((1, d), x.dtype)])      # dropped row
    contrib = out_e[slot] * w.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok].add(
        jnp.where(kept[:, None], contrib, 0))
    dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
    return y, aux._replace(dropped_frac=dropped)


def moe_ffn_ep(x: jax.Array, params: dict, *, top_k: int,
               capacity_factor: float, act, mesh: Mesh,
               data_axes: tuple[str, ...], model_axis: str = "model"
               ) -> tuple[jax.Array, MoEAux]:
    """Expert-parallel MoE: experts sharded over ``model_axis``.

    x (B, S, d) is sharded over the data axes on B and REPLICATED over the
    model axis (the standard TP activation layout), so dispatch needs no
    all_to_all at all: every peer already holds every token, slices the
    per-expert buffers of ITS OWN experts locally, and the combine is one
    psum over the model axis (the same bytes as a TP FFN all-reduce).  The
    routing computation is replicated across model peers — redundant
    arithmetic, zero communication; the paper's "every worker does the same
    cheap bookkeeping, no synchronization" trade made on silicon.
    """
    e = params["wg"].shape[0]
    m = mesh.shape[model_axis]
    assert e % m == 0, (e, m)
    el = e // m

    def body(xl, router, wg, wu, wd):
        b, s, d = xl.shape
        t = b * s
        xt = xl.reshape(t, d)
        cap = capacity(t, e, top_k, capacity_factor)
        w, ids, aux = route(xt, router, top_k)
        tok, slot, kept = _dispatch_indices(ids, e, cap)

        buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[tok])
        p = jax.lax.axis_index(model_axis)
        mine = jax.lax.dynamic_slice_in_dim(
            buf[:-1].reshape(e, cap, d), p * el, el, axis=0)    # (El, cap, d)
        out_e = _expert_ffn(mine, wg, wu, wd, act)              # (El, cap, d)
        out_full = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((e * cap, d), xt.dtype),
            out_e.reshape(el * cap, d), p * el * cap, axis=0)
        out_full = jnp.concatenate([out_full, jnp.zeros((1, d), xt.dtype)])
        contrib = out_full[slot] * w.reshape(-1)[:, None].astype(xt.dtype)
        y = jnp.zeros((t, d), xt.dtype).at[tok].add(
            jnp.where(kept[:, None], contrib, 0))
        y = jax.lax.psum(y, model_axis)                         # combine
        dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
        aux = MoEAux(jax.lax.pmean(aux.load_balance, data_axes),
                     jax.lax.pmean(aux.router_z, data_axes),
                     jax.lax.pmean(dropped, data_axes))
        return y.reshape(b, s, d), aux

    dp = data_axes if len(data_axes) > 1 else data_axes[0]
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(dp, None, None),
                   MoEAux(P(), P(), P())),
        check_vma=False)
    y, aux = fn(x, params["router"], params["wg"], params["wu"], params["wd"])
    return y, aux


def moe_ffn(x: jax.Array, params: dict, *, top_k: int, capacity_factor: float,
            act, mesh: Mesh | None = None,
            data_axes: tuple[str, ...] = ()) -> tuple[jax.Array, MoEAux]:
    """Dispatcher: (B, S, d) -> (B, S, d) plus aux losses."""
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1 \
            and params["wg"].shape[0] % mesh.shape["model"] == 0:
        return moe_ffn_ep(x, params, top_k=top_k,
                          capacity_factor=capacity_factor, act=act,
                          mesh=mesh, data_axes=data_axes)
    b, s, d = x.shape
    y, aux = moe_ffn_local(x.reshape(b * s, d), params, top_k=top_k,
                           capacity_factor=capacity_factor, act=act)
    return y.reshape(b, s, d), aux


def param_specs(cfg) -> dict:
    """ParamSpec tree for one MoE FFN layer stack (leading 'layers' dim)."""
    L, d, f, e = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    S = common.ParamSpec
    return {
        "router": S((L, d, e), ("layers", "embed", "experts_r"), scale=0.1),
        "wg": S((L, e, d, f), ("layers", "experts", "ff_in", "ff")),
        "wu": S((L, e, d, f), ("layers", "experts", "ff_in", "ff")),
        "wd": S((L, e, f, d), ("layers", "experts", "ff", "embed_out")),
    }
