"""RWKV6 "Finch": attention-free time mixing with data-dependent decay.

Per head (key/value dims n = head_dim), the recurrence is

    S_t   = diag(w_t) S_{t-1} + k_t^T v_t            (state n x n)
    out_t = r_t ( S_{t-1} + diag(u) k_t^T v_t )

with w_t = exp(-exp(ww_t)) in (0,1) produced from the token itself (the
data-dependent decay that distinguishes Finch from RWKV5), and u the
current-token bonus.

Training/prefill uses the chunked closed form: with L = inclusive cumsum of
log w inside a chunk and Lx its exclusive version, for j < t

    score[t, j] = sum_n r_t[n] k_j[n] exp(Lx_t[n] - L_j[n])     (<= 0 exponent)
    cross_t     = (r_t * exp(Lx_t)) @ S_0
    S_end       = diag(exp(L_end)) S_0 + sum_j diag(exp(L_end - L_j)) k_j^T v_j

All exponents are differences with later-minus-earlier cumsums of negative
logs, hence <= 0: the chunk math cannot overflow (the factored-matmul form
exp(Lx_t)·exp(-L_j) can, which is why the (C, C, n) einsum is used; chunks
are small).  ``rwkv_naive`` is the sequential oracle for the property tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class RwkvState(NamedTuple):
    s: jax.Array        # (B, H, n, n) wkv state (f32)
    x_tm: jax.Array     # (B, d) last token seen by time mix
    x_cm: jax.Array     # (B, d) last token seen by channel mix


LORA = 64   # decay LoRA rank (rwkv6 uses 64 for 7B)


def param_specs(cfg) -> dict:
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    n = cfg.rwkv_head_dim
    h = d // n
    S = common.ParamSpec
    return {
        # time mix
        "mix": S((L, 5, d), ("layers", None, "embed"), init="value", value=0.5),
        "w_r": S((L, d, d), ("layers", "embed", "heads_x_dim")),
        "w_k": S((L, d, d), ("layers", "embed", "heads_x_dim")),
        "w_v": S((L, d, d), ("layers", "embed", "heads_x_dim")),
        "w_g": S((L, d, d), ("layers", "embed", "heads_x_dim")),
        "w_o": S((L, d, d), ("layers", "heads_x_dim", "embed_out")),
        "decay_base": S((L, d), ("layers", "embed"), init="value", value=-5.0),
        "decay_a": S((L, d, LORA), ("layers", "embed", None), scale=0.1),
        "decay_b": S((L, LORA, d), ("layers", None, "embed"), scale=0.1),
        "bonus_u": S((L, h, n), ("layers", "kv_heads", None), init="zeros"),
        "ln_x": S((L, d), ("layers", "embed"), init="zeros"),
        # channel mix
        "mix_c": S((L, 2, d), ("layers", None, "embed"), init="value",
                   value=0.5),
        "w_ck": S((L, d, f), ("layers", "embed", "ff")),
        "w_cr": S((L, d, d), ("layers", "embed", "heads_x_dim"), scale=0.5),
        "w_cv": S((L, f, d), ("layers", "ff", "embed_out")),
        "ln1": S((L, d), ("layers", "embed"), init="zeros"),
        "ln2": S((L, d), ("layers", "embed"), init="zeros"),
    }


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x (B, S, d); last (B, d) -> previous-token sequence (B, S, d)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _decays(xw: jax.Array, p: dict) -> jax.Array:
    """Data-dependent log-decay.  Returns log w (B, S, d), strictly < 0."""
    ww = p["decay_base"] + jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    # log w = -exp(ww); clamp ww for numerical sanity
    return -jnp.exp(jnp.clip(ww.astype(jnp.float32), -12.0, 6.0))


def _group_norm(x: jax.Array, gamma: jax.Array, n: int) -> jax.Array:
    """Per-head layernorm over head_dim (rwkv's ln_x). x (B, S, H, n)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    b, s, h, _ = x.shape
    g = (1.0 + gamma.astype(jnp.float32)).reshape(h, n)
    return xn * g[None, None]


def _chunk_wkv(r, k, v, logw, u, s0):
    """One chunk of the closed-form WKV.

    r,k,v (B, C, H, n); logw (B, C, H, n); u (H, n); s0 (B, H, n, n) f32.
    Returns (out (B, C, H, n) f32, s_end)."""
    bsz, c, h, n = r.shape
    L = jnp.cumsum(logw, axis=1)                      # inclusive
    Lx = L - logw                                     # exclusive
    # intra-chunk scores: (B, H, C, C)
    expo = Lx[:, :, None, :, :] - L[:, None, :, :, :]   # (B, Ct, Cj, H, n)
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :,
                                                             None, None]
    ex = jnp.where(mask, expo, -jnp.inf)
    scores = jnp.einsum("bthn,bjhn,btjhn->bhtj", r, k,
                        jnp.exp(ex).astype(r.dtype))
    diag = jnp.einsum("bthn,hn,bthn->bht", r, u.astype(r.dtype), k)
    out = jnp.einsum("bhtj,bjhn->bthn", scores, v).astype(jnp.float32)
    out = out + diag.transpose(0, 2, 1)[..., None] * v.astype(jnp.float32)
    # cross-chunk: r_t * exp(Lx_t) against s0
    rx = r.astype(jnp.float32) * jnp.exp(Lx)
    out = out + jnp.einsum("bthn,bhnm->bthm", rx, s0)
    # state update
    kw = k.astype(jnp.float32) * jnp.exp(L[:, -1:, :, :] - L)   # (B,C,H,n)
    s_end = s0 * jnp.exp(L[:, -1])[..., None] \
        + jnp.einsum("bthn,bthm->bhnm", kw, v.astype(jnp.float32))
    return out, s_end


def time_mix(x: jax.Array, p: dict, *, head_dim: int, chunk: int = 64,
             state: RwkvState | None = None
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """RWKV6 attention replacement.  x (B, S, d) -> (out, s_end, last_x)."""
    b, s, d = x.shape
    n = head_dim
    h = d // n
    last = state.x_tm if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)
    mu = p["mix"]                                     # (5, d)
    xr, xk, xv, xw, xg = (_lerp(x, xs, mu[i]) for i in range(5))
    r = (xr @ p["w_r"]).reshape(b, s, h, n)
    k = (xk @ p["w_k"]).reshape(b, s, h, n)
    v = (xv @ p["w_v"]).reshape(b, s, h, n)
    g = xg @ p["w_g"]
    logw = _decays(xw, p).reshape(b, s, h, n)

    s0 = (state.s if state is not None
          else jnp.zeros((b, h, n, n), jnp.float32))
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c

    def step(carry, inp):
        rc, kc, vc, wc = inp
        out, s_end = _chunk_wkv(rc, kc, vc, wc, p["bonus_u"], carry)
        return s_end, out

    resh = lambda a: a.reshape(b, nc, c, h, n).swapaxes(0, 1)
    s_end, outs = jax.lax.scan(step, s0, (resh(r), resh(k), resh(v),
                                          resh(logw)))
    out = outs.swapaxes(0, 1).reshape(b, s, h, n)

    out = _group_norm(out, p["ln_x"], n).reshape(b, s, d)
    out = (out * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    return out @ p["w_o"], s_end, x[:, -1, :]


def channel_mix(x: jax.Array, p: dict, *,
                state: RwkvState | None = None) -> tuple[jax.Array, jax.Array]:
    """RWKV6 FFN. x (B, S, d) -> (out, last_x)."""
    b, s, d = x.shape
    last = state.x_cm if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)
    mu = p["mix_c"]
    xk = _lerp(x, xs, mu[0])
    xr = _lerp(x, xs, mu[1])
    kk = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    rr = jax.nn.sigmoid((xr @ p["w_cr"]).astype(jnp.float32)).astype(x.dtype)
    return rr * (kk @ p["w_cv"]), x[:, -1, :]


def rwkv_layer(x: jax.Array, p: dict, *, head_dim: int, chunk: int = 64,
               state: RwkvState | None = None
               ) -> tuple[jax.Array, RwkvState]:
    """One full RWKV block: time mix + channel mix, pre-norm residual."""
    att, s_end, x_tm = time_mix(common.rmsnorm(x, p["ln1"]), p,
                                head_dim=head_dim, chunk=chunk, state=state)
    x = x + att
    ffn, x_cm = channel_mix(common.rmsnorm(x, p["ln2"]), p, state=state)
    return x + ffn, RwkvState(s=s_end, x_tm=x_tm, x_cm=x_cm)


def rwkv_naive_wkv(r, k, v, logw, u, s0):
    """Sequential oracle for the WKV recurrence. Shapes as _chunk_wkv."""
    def step(s, inp):
        rt, kt, vt, wt = inp                          # (B, H, n)
        kv = kt[..., :, None] * vt[..., None, :]      # (B, H, n, n)
        att = s + u[None, :, :, None] * kv.astype(jnp.float32)
        out = jnp.einsum("bhn,bhnm->bhm", rt, att.astype(rt.dtype))
        s = jnp.exp(wt.astype(jnp.float32))[..., None] * s \
            + kv.astype(jnp.float32)
        return s, out

    sw = lambda a: a.swapaxes(0, 1).swapaxes(1, 2)    # (B,C,H,n)->(C,B,H,n)
    args = tuple(a.swapaxes(0, 1) for a in (r, k, v, logw))
    s_end, outs = jax.lax.scan(step, s0, args)
    return outs.swapaxes(0, 1).astype(jnp.float32), s_end
