"""Attention: chunked online-softmax (flash-style in pure JAX) for training
and prefill, plus KV-cache decode (full cache and ring-buffer SWA cache).

Design (DESIGN.md §7):
  * training/prefill never materialize (S, S) scores: an outer ``lax.scan``
    over query chunks and an inner scan over KV chunks carry the running
    (max, denominator, accumulator) triple — block memory is
    (B, KV, G, Cq, Ck);
  * GQA is computed grouped — queries reshaped to (B, S, KV, G, hd) so KV is
    never repeated in memory;
  * ``swa`` attention slices a static-width KV window per query chunk
    (``window + Cq`` wide) instead of sweeping all KV chunks: cost is
    O(S·W) not O(S²), which is what makes the 500k cells affordable;
  * the baseline "full" path sweeps the whole rectangle with a causal mask
    (2× the useful FLOPs).  ``triangular=True`` switches to a block-
    triangular schedule (skips fully-masked KV chunks per query chunk) — a
    §Perf optimization measured in EXPERIMENTS.md;
  * decode attends one new token against the cache, chunk-scanned, with a
    position mask; SWA decode uses a ring buffer of width ``window``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map

NEG = -1.0e30


def _block_attn(q, k, v, mask, sm_scale):
    """One online-softmax block.

    q (B, Cq, KV, G, hd); k, v (B, Ck, KV, hd); mask (B or 1, KV or 1, G or 1,
    Cq, Ck) bool. Returns (scores_max (..., Cq), exp_sum, weighted_v) with
    leading dims (B, KV, G).
    """
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(mask, s, NEG)
    m = jnp.max(s, axis=-1)                                   # (B,KV,G,Cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                   # (B,KV,G,Cq)
    o = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


# Flash-style backward: recompute each block's scores instead of saving
# them.  Without this, the (Cq, Ck|span)-sized score/prob tensors become
# per-iteration residuals of the inner attention scans and get STACKED over
# the trip count — measured as ~60% of hymba train_4k's HBM bytes
# (EXPERIMENTS.md §Perf iteration 3).  The block inputs (q/k/v tiles) are
# loop-slices of already-saved tensors, so the only cost is ~1 extra block
# forward inside the backward pass.
_block_attn_ckpt = jax.checkpoint(_block_attn)


def _merge(m1, l1, o1, m2, l2, o2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, l1 * a1 + l2 * a2, o1 * a1[..., None] + o2 * a2[..., None]


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True, window: int = 0, chunk: int = 512,
           q_offset: jax.Array | int = 0, sm_scale: float | None = None,
           triangular: bool = False) -> jax.Array:
    """Chunked attention.  q (B, Sq, H, hd); k, v (B, Sk, KVH, hd).

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation / cross-attn alignment).  ``window > 0`` = sliding-window
    (causal implied).  Returns (B, Sq, H, hd), q.dtype.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    def _div_chunk(s, want):
        c = min(want, s)
        while s % c:
            c -= 1
        return c

    cq = _div_chunk(sq, chunk)
    ck = _div_chunk(sk, chunk)
    assert sq % cq == 0 and sk % ck == 0, (sq, cq, sk, ck)
    nq, nk = sq // cq, sk // ck
    qg = q.reshape(b, sq, kvh, g, hd)

    if window:
        return _attend_swa(qg, k, v, window=window, cq=cq,
                           q_offset=q_offset, scale=scale
                           ).reshape(b, sq, h, hd)
    if causal and triangular and nq > 1:
        return _attend_triangular(qg, k, v, cq=cq, ck=ck,
                                  q_offset=q_offset, scale=scale
                                  ).reshape(b, sq, h, hd)

    def q_step(_, iq):
        qi = jax.lax.dynamic_slice_in_dim(qg, iq * cq, cq, axis=1)
        qpos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, ik):
            m0, l0, o0 = carry
            ki = jax.lax.dynamic_slice_in_dim(k, ik * ck, ck, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, ik * ck, ck, axis=1)
            kpos = ik * ck + jnp.arange(ck)
            if causal:
                mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
            else:
                mask = jnp.ones((1, 1, 1, cq, ck), bool)
            m2, l2, o2 = _block_attn_ckpt(qi, ki, vi, mask, scale)
            return _merge(m0, l0, o0, m2, l2, o2), None

        init = (jnp.full((b, kvh, g, cq), NEG, jnp.float32),
                jnp.zeros((b, kvh, g, cq), jnp.float32),
                jnp.zeros((b, kvh, g, cq, hd), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = o / jnp.maximum(l[..., None], 1e-30)            # (B,KV,G,Cq,hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))      # (nq,B,KV,G,Cq,hd)
    out = jnp.moveaxis(outs, 0, 1)                            # (B,nq,KV,G,Cq,hd)
    out = jnp.moveaxis(out, 4, 2)                             # (B,nq,Cq,KV,G,hd)
    return out.reshape(b, sq, h, hd)


def _attend_triangular(qg, k, v, *, cq: int, ck: int, q_offset, scale):
    """Block-triangular causal schedule (§Perf optimization).

    The baseline sweeps the full nq×nk rectangle and masks; here we scan the
    *static list of causally-live block pairs* (i, j) with j·ck < (i+1)·cq +
    q_offset, accumulating per-query-chunk online-softmax state at slice i.
    HLO FLOPs drop to ~the triangle (~2× for square self-attention) at the
    price of a serialized pair scan — batch/head parallelism is untouched.
    Requires a static q_offset.
    """
    b, sq, kvh, g, hd = qg.shape
    sk = k.shape[1]
    nq, nk = sq // cq, sk // ck
    off = int(q_offset)
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if j * ck < off + (i + 1) * cq]
    i_idx = jnp.asarray([p[0] for p in pairs], jnp.int32)
    j_idx = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def pair_step(carry, ij):
        m_all, l_all, o_all = carry                 # (nq, B, KV, G, Cq[, hd])
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        qpos = off + i * cq + jnp.arange(cq)
        kpos = j * ck + jnp.arange(ck)
        mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
        m2, l2, o2 = _block_attn_ckpt(qi, ki, vi, mask, scale)
        m0 = jax.lax.dynamic_index_in_dim(m_all, i, 0, keepdims=False)
        l0 = jax.lax.dynamic_index_in_dim(l_all, i, 0, keepdims=False)
        o0 = jax.lax.dynamic_index_in_dim(o_all, i, 0, keepdims=False)
        m, l, o = _merge(m0, l0, o0, m2, l2, o2)
        upd = lambda a, x: jax.lax.dynamic_update_index_in_dim(a, x, i, 0)
        return (upd(m_all, m), upd(l_all, l), upd(o_all, o)), None

    init = (jnp.full((nq, b, kvh, g, cq), NEG, jnp.float32),
            jnp.zeros((nq, b, kvh, g, cq), jnp.float32),
            jnp.zeros((nq, b, kvh, g, cq, hd), jnp.float32))
    (m, l, o), _ = jax.lax.scan(pair_step, init, (i_idx, j_idx))
    out = o / jnp.maximum(l[..., None], 1e-30)      # (nq,B,KV,G,Cq,hd)
    out = jnp.moveaxis(out, 0, 1)                   # (B,nq,KV,G,Cq,hd)
    out = jnp.moveaxis(out, 4, 2)                   # (B,nq,Cq,KV,G,hd)
    return out.astype(qg.dtype).reshape(b, sq, kvh * g, hd)


def _attend_swa(qg, k, v, *, window: int, cq: int, q_offset, scale):
    """Sliding-window attention: per query chunk, slice a static KV window.

    Window slice width is ``window + cq`` rounded so cost is O(S·W).
    """
    b, sq, kvh, g, hd = qg.shape
    sk = k.shape[1]
    nq = sq // cq
    span = min(window + cq, sk)

    def q_step(_, iq):
        qi = jax.lax.dynamic_slice_in_dim(qg, iq * cq, cq, axis=1)
        qpos = q_offset + iq * cq + jnp.arange(cq)            # (Cq,)
        # earliest key any query in this chunk may see
        start = jnp.clip(q_offset + iq * cq - window + 1, 0, sk - span)
        ki = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kpos = start + jnp.arange(span)
        mask = ((qpos[:, None] >= kpos[None, :])
                & (qpos[:, None] - kpos[None, :] < window))[None, None, None]
        m, l, o = _block_attn_ckpt(qi, ki, vi, mask, scale)
        out = o / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(qg.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1)
    out = jnp.moveaxis(out, 4, 2)
    return out.reshape(b, sq, kvh * g, hd)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  pos: jax.Array, *, window: int = 0, chunk: int = 1024,
                  sm_scale: float | None = None) -> jax.Array:
    """One-token decode. q (B, 1, H, hd); caches (B, S, KVH, hd).

    ``pos`` (scalar or (B,)): index of the NEW token (keys at indices > pos
    are masked).  For ``window > 0`` the cache is a ring buffer of width
    ``window`` written at ``pos % window`` — masking handles wrap-around.
    Chunk-scanned flash-decoding style (partials merged by LSE), so the
    (B, S) score row is never materialized for 500k caches.
    """
    b, _, h, hd = q.shape
    sk, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    ck = min(chunk, sk)
    nk = sk // ck
    qg = q.reshape(b, 1, kvh, g, hd)
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))

    def kv_step(carry, ik):
        m0, l0, o0 = carry
        ki = jax.lax.dynamic_slice_in_dim(k_cache, ik * ck, ck, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v_cache, ik * ck, ck, axis=1)
        slot = ik * ck + jnp.arange(ck)                       # (Ck,)
        if window:
            # ring buffer: slot s holds absolute position p iff
            # p % window == s and pos - window < p <= pos
            age = (pos[:, None] - slot[None, :]) % window      # (B, Ck)
            abs_pos = pos[:, None] - age
            valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
        else:
            valid = slot[None, :] <= pos[:, None]
        mask = valid[:, None, None, None, :]                  # (B,1,1,1,Ck)
        m2, l2, o2 = _block_attn(qg, ki, vi, mask, scale)
        return _merge(m0, l0, o0, m2, l2, o2), None

    init = (jnp.full((b, kvh, g, 1), NEG, jnp.float32),
            jnp.zeros((b, kvh, g, 1), jnp.float32),
            jnp.zeros((b, kvh, g, 1, hd), jnp.float32))
    (m, l, o), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)                             # (B,1,KV,G,hd)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def decode_attend_seqsharded(q: jax.Array, k_new: jax.Array,
                             v_new: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, pos: jax.Array, *,
                             mesh, axes: tuple[str, ...],
                             b_axes: tuple[str, ...] = (),
                             chunk: int = 1024,
                             sm_scale: float | None = None):
    """Flash-decoding over a KV cache sequence-sharded on ``axes``.

    Two users: long-context cells (batch=1, sequence over the DATA axes)
    and GQA decode where kv_heads doesn't divide the model axis (sequence
    over the MODEL axis — head_dim sharding makes GSPMD all-gather the
    cache; replication blows HBM; see EXPERIMENTS.md §Perf iteration 2).

    The whole cache transaction lives inside one shard_map: the owning
    shard does a masked write of the new token's K/V into its local chunk,
    every shard computes a partial online-softmax over its chunk (positions
    offset by the shard index), and partials merge with one max/sum
    reduction (B x KVH x G scalars — the same tiny collective footprint as
    the index's BSF protocol).  Returns (out, new_k_cache, new_v_cache).
    """
    from jax.sharding import PartitionSpec as P
    b, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    ax = axes if len(axes) > 1 else axes[0]
    bp = (b_axes if len(b_axes) > 1 else b_axes[0]) if b_axes else None

    def body(qf, knf, vnf, kf, vf, posf):
        bl = qf.shape[0]
        sloc = kf.shape[1]
        idx = jax.lax.axis_index(ax)
        base = idx * sloc
        posb = jnp.broadcast_to(jnp.asarray(posf), (bl,))
        # masked write of the new token into the owning shard's chunk
        local = jnp.clip(posb - base, 0, sloc - 1)            # (B,)
        mine = (posb >= base) & (posb < base + sloc)          # (B,)

        def write(cache, new):
            def one(c, n, s, m):
                cur = jax.lax.dynamic_slice_in_dim(c, s, 1, axis=0)
                upd = jnp.where(m, n.astype(c.dtype), cur)
                return jax.lax.dynamic_update_slice_in_dim(c, upd, s, axis=0)
            return jax.vmap(one)(cache, new, local, mine)

        kf = write(kf, knf)
        vf = write(vf, vnf)

        qg = qf.reshape(bl, 1, kvh, g, hd)
        ck = min(chunk, sloc)
        nk = sloc // ck

        def kv_step(carry, ik):
            m0, l0, o0 = carry
            ki = jax.lax.dynamic_slice_in_dim(kf, ik * ck, ck, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(vf, ik * ck, ck, axis=1)
            slot = base + ik * ck + jnp.arange(ck)
            valid = slot[None, :] <= posb[:, None]
            mask = valid[:, None, None, None, :]
            m2, l2, o2 = _block_attn(qg, ki, vi, mask, scale)
            return _merge(m0, l0, o0, m2, l2, o2), None

        init = (jnp.full((bl, kvh, g, 1), NEG, jnp.float32),
                jnp.zeros((bl, kvh, g, 1), jnp.float32),
                jnp.zeros((bl, kvh, g, 1, hd), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        # cross-shard LSE merge
        mg = jax.lax.pmax(m, ax)
        a = jnp.exp(m - mg)
        lg = jax.lax.psum(l * a, ax)
        og = jax.lax.psum(o * a[..., None], ax)
        out = og / jnp.maximum(lg[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1)
        return out.reshape(bl, 1, h, hd).astype(qf.dtype), kf, vf

    cache_spec = P(bp, ax, None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bp, None, None, None), P(bp, None, None, None),
                  P(bp, None, None, None), cache_spec, cache_spec, P()),
        out_specs=(P(bp, None, None, None), cache_spec, cache_spec),
        check_vma=False)
    return fn(q, k_new, v_new, k_cache, v_cache, jnp.asarray(pos))


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, window: int = 0):
    """Write one new token's K/V at position ``pos`` (ring slot if SWA)."""
    b = k_new.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    slot = pos % window if window else pos

    def write(cache, new):
        def one(c, n, s):
            return jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
        return jax.vmap(one)(cache, new, slot)

    return write(k_cache, k_new), write(v_cache, v_new)
