"""Shared model machinery: parameter trees with logical sharding axes,
norms, RoPE, activations, and the logical→mesh PartitionSpec resolver.

Params are plain nested dicts of arrays.  Each leaf's *logical axes* (one
name per dim, e.g. ``("layers", "embed", "q_heads", "head_dim")``) are
recorded in a parallel tree at init time; ``resolve_pspecs`` turns them into
``PartitionSpec``s for a given mesh with divisibility-checked fallbacks —
e.g. GQA KV heads (8) on a 16-way model axis fall through to the fused
``kv×head_dim`` dim.  This is the logical-axis-rules pattern of MaxText /
Flax partitioning, self-contained.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# ---------------------------------------------------------------------------
# Param spec construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | value
    scale: float = 1.0
    value: float = 0.0
    dtype: Any = jnp.float32

    def make(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "value":
            return jnp.full(self.shape, self.value, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / np.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, self.shape)).astype(self.dtype)


def build_params(specs: dict, key: jax.Array, dtype=jnp.float32):
    """Instantiate a nested dict of ParamSpec into arrays (split keys)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, spec in zip(keys, leaves):
        arr = spec.make(k)
        if spec.init == "normal":
            arr = arr.astype(dtype)
        vals.append(arr)
    return jax.tree.unflatten(treedef, vals)


def params_shape_tree(specs: dict, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) — the dry-run path."""
    def f(s: ParamSpec):
        dt = dtype if s.init == "normal" else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_tree(specs: dict):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Logical axis -> mesh axis resolution
# ---------------------------------------------------------------------------

# mesh-axis placement preferences per logical axis, tried in order; a
# placement is taken only if the dim size divides the mesh axis size.
MODEL_AXIS_PRIORITY = ("experts", "vocab", "ff", "q_heads", "kv_fused",
                       "kv_heads", "d_inner", "heads_x_dim", "embed_out")
FSDP_AXIS_PRIORITY = ("embed", "ff_in", "frames")


def _place(dims: tuple[str | None, ...], shape: tuple[int, ...],
           priority: tuple[str, ...], mesh_size: int,
           taken: set[int]) -> int | None:
    for want in priority:
        for i, name in enumerate(dims):
            if name == want and i not in taken and shape[i] % mesh_size == 0:
                return i
    return None


def resolve_pspec(axes: tuple[str | None, ...], shape: tuple[int, ...],
                  mesh: Mesh, *, fsdp: bool, data_axes: tuple[str, ...],
                  model_axis: str = "model") -> P:
    """One leaf's PartitionSpec from its logical axes under divisibility."""
    entries: list[Any] = [None] * len(axes)
    taken: set[int] = set()
    msize = int(np.prod([mesh.shape[a] for a in (model_axis,)])) \
        if model_axis in mesh.axis_names else 1
    if msize > 1:
        i = _place(axes, shape, MODEL_AXIS_PRIORITY, msize, taken)
        if i is not None:
            entries[i] = model_axis
            taken.add(i)
    if fsdp and data_axes:
        dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
        i = _place(axes, shape, FSDP_AXIS_PRIORITY, dsize, taken)
        if i is not None:
            entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            taken.add(i)
    return P(*entries)


def resolve_pspecs(axes_t, shapes_t, mesh: Mesh, *, fsdp: bool,
                   data_axes: tuple[str, ...]) -> Any:
    """PartitionSpec tree for a whole param tree."""
    flat_axes, treedef = jax.tree.flatten(
        axes_t, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))
    flat_shapes = treedef.flatten_up_to(shapes_t)
    out = [resolve_pspec(a, tuple(s.shape), mesh, fsdp=fsdp,
                         data_axes=data_axes)
           for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def activation(kind: str) -> Callable[[jax.Array], jax.Array]:
    if kind == "silu":
        return jax.nn.silu
    if kind == "gelu":
        return jax.nn.gelu
    if kind == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs
    # (..., S, 1, half) — broadcasts over the heads dim
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)
