"""Staged, sharded, resumable out-of-core build pipeline (DESIGN.md §5).

The write path behind every on-disk index:

    runs.py      pass-1 workers -> sorted summary run files (one/shard)
    merge.py     k-way external merge -> global block order
    driver.py    stage orchestration, manifest resume, pass-2 permute
    manifest.py  the JSON resume ledger (per-unit records, checksums)

``driver.run_pipeline`` is the full-control entry point (returns the
instrumented ``BuildReport``); ``driver.pipeline_build`` returns the
built index opened out-of-core; ``ooc_build.build_on_disk`` is the
monolithic single-worker wrapper kept for the original callers.  The
run/merge interfaces are source-agnostic so the future LSM
delta-compaction job can feed delta runs through the same merge.
"""
from repro.storage.pipeline.driver import (BuildInterrupted, BuildReport,
                                           StageCounters, pipeline_build,
                                           run_pipeline)
from repro.storage.pipeline.manifest import Manifest
from repro.storage.pipeline.merge import merge_order, merge_runs, open_merge
from repro.storage.pipeline.runs import SummaryBuilder, build_run, open_run

__all__ = [
    "run_pipeline", "pipeline_build", "BuildReport", "StageCounters",
    "BuildInterrupted", "Manifest",
    "build_run", "open_run", "SummaryBuilder",
    "merge_runs", "merge_order", "open_merge",
]
