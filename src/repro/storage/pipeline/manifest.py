"""Build manifest: the resume ledger of the staged pipeline (DESIGN.md §5).

One JSON file per build, living in the build's work directory next to the
run files and the partial index.  It records

  * a **fingerprint** of everything that determines the output bytes
    (source file identity, n/w/card/capacity, normalize, extra, format
    version) — a resume against a manifest whose fingerprint differs is
    a DIFFERENT build and starts fresh;
  * the **layout** the driver planned (shard ranges, permute-unit rows):
    resume always reuses the recorded layout, so a caller changing
    ``chunk``/``workers`` between attempts cannot shift unit boundaries
    under completed work;
  * per-stage **unit records**: each completed unit of work (a sorted
    run, the merge, the summary sections, one permute unit, publish) is
    recorded — with sha256+size for the stages that produce standalone
    files — only AFTER its bytes are flushed, so a SIGKILL at any point
    leaves a manifest whose records are all true.

Every save is atomic (temp + fsync + rename): the manifest itself can
never be read half-written.  The driver's resume rule is then one line:
a unit is skipped iff its record exists and (for file-producing units)
its file still checks out.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path

MANIFEST_VERSION = 1
STAGES = ("runs", "merge", "summaries", "permute", "publish")


def file_digest(path: str | Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                return h.hexdigest()
            h.update(b)


def file_record(path: str | Path) -> dict:
    """The integrity record stored for a unit that produced ``path``."""
    return {"path": Path(path).name, "bytes": os.path.getsize(path),
            "sha256": file_digest(path)}


def file_ok(path: str | Path, record: dict) -> bool:
    """Does ``path`` still match its manifest record? (resume validation)"""
    path = Path(path)
    if not path.exists() or os.path.getsize(path) != record["bytes"]:
        return False
    return file_digest(path) == record["sha256"]


@dataclasses.dataclass
class Manifest:
    path: Path
    data: dict

    @classmethod
    def fresh(cls, path: str | Path, *, fingerprint: dict,
              layout: dict) -> "Manifest":
        m = cls(Path(path), {
            "manifest_version": MANIFEST_VERSION,
            "fingerprint": fingerprint,
            "layout": layout,
            "stages": {s: {} for s in STAGES},
        })
        m.save()
        return m

    @classmethod
    def load(cls, path: str | Path) -> "Manifest | None":
        path = Path(path)
        if not path.exists():
            return None
        try:
            data = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return None        # unreadable ledger == no ledger
        if data.get("manifest_version") != MANIFEST_VERSION:
            return None
        return cls(path, data)

    @property
    def fingerprint(self) -> dict:
        return self.data["fingerprint"]

    @property
    def layout(self) -> dict:
        return self.data["layout"]

    def units(self, stage: str) -> dict:
        """unit-id -> record for every COMPLETED unit of ``stage``."""
        return self.data["stages"][stage]

    def record_unit(self, stage: str, unit: str, record: dict | None = None,
                    save: bool = True) -> None:
        self.data["stages"][stage][str(unit)] = record or {}
        if save:
            self.save()

    def clear_stage(self, *stages: str, save: bool = True) -> None:
        for s in stages:
            self.data["stages"][s] = {}
        if save:
            self.save()

    def save(self) -> None:
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self.data, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
