"""The staged, sharded, resumable build driver (DESIGN.md §5).

Orchestrates the full write path as restartable stages over recorded
units of work:

  runs       parallel pass-1 workers (one per source shard) each stream
             their shard through the summarize kernel and publish a
             sorted summary run file               (unit = one shard)
  merge      k-way external merge of the runs into the global block
             order, never materializing all summaries (unit = the merge)
  summaries  ids/slo/shi/elo/ehi sections computed from the merged sax
             words in block groups and written into the PARTIAL index
             file                                  (unit = the stage)
  permute    pass 2: gather each unit's rows off the source memmap in
             merged order (random reads), z-normalize on device, and
             positioned-write into the raw section (sequential writes)
                                                   (unit = a row range)
  publish    fsync + atomic rename of the partial onto the final name

Every unit records its completion in the JSON manifest (manifest.py)
only after its bytes are flushed, and every output file publishes via
temp + atomic rename — so a build killed at ANY instant resumes from
the last completed unit instead of restarting, and redoing the one
interrupted unit rewrites identical bytes (positioned writes are
idempotent).  The finished file is byte-identical to
``save_index(core.build(...))`` on the same data, whatever the shard
count, worker count, or kill/resume history (tests/test_pipeline.py).

Test/bench instrumentation: the ``REPRO_BUILD_KILL_AFTER`` env var
("<stage>:<k>") SIGKILLs the process after the k-th completed unit of a
stage — a real, uncatchable kill for the crash-resume tests — and the
``fault=`` hook lets benchmarks raise ``BuildInterrupted`` in-process at
the same points.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.core import index as index_lib
from repro.core import isax
from repro.core.index import RAW_PAD, BlockIndex
from repro.storage import format as format_lib
from repro.storage.format import IndexFileWriter, SeriesStore
from repro.storage.pipeline import merge as merge_lib
from repro.storage.pipeline import runs as runs_lib
from repro.storage.pipeline.manifest import (Manifest, file_ok, file_record)

KILL_ENV = "REPRO_BUILD_KILL_AFTER"
STAGES = ("runs", "merge", "summaries", "permute", "publish")


class BuildInterrupted(RuntimeError):
    """Raised by a ``fault=`` hook to interrupt a build in-process (the
    bench's injected kill); the partial state is kept for resume."""


@dataclasses.dataclass
class StageCounters:
    built: int = 0     # units executed in THIS invocation
    reused: int = 0    # units skipped because the manifest proved them done


@dataclasses.dataclass
class BuildReport:
    """Instrumented per-stage unit accounting of one driver invocation —
    the resume tests assert 'only incomplete units were redone' on it."""
    resumed: bool
    stages: dict[str, StageCounters]
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return {"resumed": self.resumed, "wall_s": self.wall_s,
                **{f"{s}_{f}": getattr(c, f) for s, c in self.stages.items()
                   for f in ("built", "reused")}}


def _maybe_kill(stage: str, done_units: int, fault) -> None:
    if fault is not None:
        fault(stage, done_units)
    spec = os.environ.get(KILL_ENV)
    if spec:
        st, _, k = spec.partition(":")
        if st == stage and done_units >= int(k):
            os.kill(os.getpid(), signal.SIGKILL)   # no cleanup, by design


@sanitize.guarded
class _UnitRecorder:
    """The one mutation point shared by concurrent stage workers:
    manifest record + report counter + fault hook, as a single atomic
    step under one lock (formerly a function-local ``lock`` the
    checker could not see).  ``flush`` runs inside the same critical
    section so 'recorded' still implies 'survives a SIGKILL'."""

    def __init__(self, man: Manifest, report: BuildReport, fault):
        self._lock = sanitize.create_lock()
        self._man = man          # guarded by: _lock
        self._report = report    # guarded by: _lock
        self._fault = fault

    def record(self, stage: str, uid, rec: dict | None = None, *,
               flush=None) -> None:
        with self._lock:
            if flush is not None:
                flush()          # recorded == survives a SIGKILL
            self._man.record_unit(stage, uid, rec)
            self._report.stages[stage].built += 1
            _maybe_kill(stage, self._report.stages[stage].built,
                        self._fault)


def _plan_layout(n_series: int, capacity: int, chunk: int,
                 n_shards: int) -> dict:
    cap, n_blocks, n_padded = index_lib.block_layout(n_series, capacity)
    shards = [[(i * n_series) // n_shards, ((i + 1) * n_series) // n_shards]
              for i in range(n_shards)]
    # permute unit = the monolithic builder's pass-2 step size: whole
    # blocks, at least `chunk` rows — unit boundaries are layout, recorded
    # in the manifest, so resume can never shift them under done work
    unit_rows = max(1, max(chunk, cap) // cap) * cap
    return {"cap": cap, "n_blocks": n_blocks, "n_padded": n_padded,
            "chunk": chunk, "unit_rows": unit_rows, "shards": shards}


def _jsonable(d: dict) -> dict:
    return json.loads(json.dumps(d))


def run_pipeline(source, out_path: str | Path, *, length: int | None = None,
                 w: int = isax.W, card: int = isax.CARD, capacity: int = 512,
                 chunk: int = 1 << 14, normalize: bool = True,
                 extra: dict | None = None, workers: int = 1,
                 shards: int | None = None,
                 work_dir: str | Path | None = None, resume: bool = True,
                 keep_work: bool = False, progress=None,
                 fault=None) -> tuple[Path, BuildReport]:
    """Run (or resume) the staged build; -> (index path, stage report).

    ``shards`` defaults to ``workers``; both default to the monolithic
    shape (1), which ``ooc_build.build_on_disk`` wraps.  ``work_dir``
    (default ``<out_path>.build/``) holds the manifest, run files, merge
    file, and the partial index — it must live on the same filesystem as
    ``out_path`` for the atomic publish.  On resume the manifest's
    recorded layout wins: changing ``chunk``/``workers``/``shards``
    between attempts re-sizes nothing that is already done.
    """
    store = source if isinstance(source, SeriesStore) else \
        SeriesStore(path=Path(source), length=length)
    out_path = Path(out_path)
    n_series, n = store.n_series, store.length
    say = progress or (lambda msg: None)
    t0 = time.perf_counter()
    report = BuildReport(resumed=False,
                         stages={s: StageCounters() for s in STAGES})

    fingerprint = _jsonable({
        "format_version": format_lib.VERSION,
        "source": str(Path(store.path).resolve()),
        "source_bytes": store.nbytes,
        "n_series": n_series, "length": n, "w": w, "card": card,
        "capacity": capacity, "normalize": normalize,
        "extra": dict(extra or {}),
    })
    n_shards = max(1, min(shards if shards is not None else max(workers, 1),
                          n_series))
    layout = _plan_layout(n_series, capacity, chunk, n_shards)

    work_dir = Path(work_dir) if work_dir is not None else \
        out_path.with_name(out_path.name + ".build")
    work_dir.mkdir(parents=True, exist_ok=True)
    man = Manifest.load(work_dir / "manifest.json") if resume else None
    if man is not None and man.fingerprint == fingerprint:
        layout = man.layout                      # recorded layout wins
        report.resumed = any(man.units(s) for s in STAGES)
        if report.resumed:
            say(f"resuming from manifest: "
                + ", ".join(f"{s} {len(man.units(s))} done" for s in STAGES
                            if man.units(s)))
    else:
        if man is not None:
            say("manifest does not match this build's parameters/source — "
                "starting fresh")
        man = Manifest.fresh(work_dir / "manifest.json",
                             fingerprint=fingerprint, layout=_jsonable(layout))

    # a previous invocation finished everything but was killed between
    # publish and cleanup: the output is already complete and verified
    pub = man.units("publish").get("0")
    if pub and out_path.exists() and file_ok(out_path, pub):
        for s in STAGES:
            report.stages[s].reused = len(man.units(s))
        report.wall_s = time.perf_counter() - t0
        say(f"{out_path} already published and verified — nothing to do")
        if not keep_work:
            shutil.rmtree(work_dir, ignore_errors=True)
        return out_path, report

    cap, n_blocks, n_padded = \
        layout["cap"], layout["n_blocks"], layout["n_padded"]
    recorder = _UnitRecorder(man, report, fault)

    # -- stage 1: sorted summary runs, one unit per shard ----------------
    run_path = lambda i: work_dir / f"run-{i:05d}.dsix"
    todo = []
    for i, (a, b) in enumerate(layout["shards"]):
        rec = man.units("runs").get(str(i))
        if rec and file_ok(run_path(i), rec):
            report.stages["runs"].reused += 1
        else:
            todo.append((i, a, b))
    if todo:
        say(f"pass 1: building {len(todo)} of {len(layout['shards'])} "
            f"sorted runs ({report.stages['runs'].reused} reused), "
            f"{workers} worker(s)")

    def _one_run(i: int, a: int, b: int) -> None:
        runs_lib.build_run(store, run_path(i), row_start=a, row_stop=b,
                           w=w, card=card, chunk=layout["chunk"],
                           normalize=normalize)
        recorder.record("runs", i, file_record(run_path(i)))

    if workers > 1 and len(todo) > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            list(ex.map(lambda t: _one_run(*t), todo))
    else:
        for t in todo:
            _one_run(*t)

    # -- stage 2: k-way external merge -> global block order -------------
    merged_path = work_dir / "merged.dsix"
    rec = man.units("merge").get("0")
    if rec and file_ok(merged_path, rec):
        report.stages["merge"].reused += 1
    else:
        say(f"merging {len(layout['shards'])} runs -> global block order")
        merge_lib.merge_runs([run_path(i)
                              for i in range(len(layout["shards"]))],
                             merged_path, w=w)
        recorder.record("merge", "0", file_record(merged_path))
    _, merged = merge_lib.open_merge(merged_path)
    order_mm, sax_mm = merged["ids"], merged["sax"]

    # -- the partial index file (stable temp name, resumable) ------------
    wr = IndexFileWriter(out_path, n=n, w=w, card=card, capacity=cap,
                         n_real=n_series, n_blocks=n_blocks, extra=extra,
                         tmp_path=work_dir / "index.partial", resume=True)
    if not wr.resumed and (man.units("summaries") or man.units("permute")):
        # the partial vanished (or its header changed): records about its
        # contents are stale — redo those stages into the fresh file
        man.clear_stage("summaries", "permute")
        say("partial index file missing — rebuilding its sections")
    try:
        # -- stage 3: summary sections, streamed in block groups ---------
        if "0" in man.units("summaries"):
            report.stages["summaries"].reused += 1
        else:
            say("writing summary sections (ids/slo/shi/elo/ehi)")
            elo = np.empty((w, n_blocks), np.float32)
            ehi = np.empty((w, n_blocks), np.float32)
            group = max(1, layout["unit_rows"] // cap)     # blocks at once
            for g0 in range(0, n_blocks, group):
                g1 = min(g0 + group, n_blocks)
                r0, r1 = g0 * cap, g1 * cap                # padded rows
                real = min(r1, n_series) - r0
                ids_rows = np.full((r1 - r0,), -1, np.int32)
                lo = np.full((r1 - r0, w), isax.SENTINEL, np.float32)
                hi = np.full((r1 - r0, w), isax.SENTINEL, np.float32)
                if real > 0:
                    ids_rows[:real] = np.array(order_mm[r0:r0 + real])
                    b = isax.bounds_from_sax(
                        np.array(sax_mm[r0:r0 + real]), card, xp=np)
                    lo[:real], hi[:real] = b[..., 0], b[..., 1]
                ids_b = ids_rows.reshape(g1 - g0, cap)
                slo = np.transpose(lo.reshape(g1 - g0, cap, w), (0, 2, 1))
                shi = np.transpose(hi.reshape(g1 - g0, cap, w), (0, 2, 1))
                el, eh = index_lib.block_envelopes(slo, shi, ids_b, xp=np)
                elo[:, g0:g1] = el.astype(np.float32)
                ehi[:, g0:g1] = eh.astype(np.float32)
                wr.write_rows("ids", g0, ids_b)
                wr.write_rows("slo", g0, slo)
                wr.write_rows("shi", g0, shi)
            wr.write_section("elo", elo)
            wr.write_section("ehi", ehi)
            recorder.record("summaries", "0", flush=wr.flush)

        # -- stage 4: external permute of raw rows, unit = row range -----
        prep = jax.jit(isax.znorm) if normalize else \
            jax.jit(lambda x: x.astype(jnp.float32))
        mm = store.memmap()
        unit_rows = layout["unit_rows"]
        units = [(str(u), s, min(s + unit_rows, n_series))
                 for u, s in enumerate(range(0, n_series, unit_rows))]
        if n_padded > n_series:
            units.append(("pad", n_series, n_padded))
        todo_u = [u for u in units if u[0] not in man.units("permute")]
        report.stages["permute"].reused = len(units) - len(todo_u)
        if todo_u:
            say(f"pass 2: permuting {len(todo_u)} of {len(units)} raw "
                f"units ({report.stages['permute'].reused} reused)")

        def _one_unit(uid: str, s: int, e: int) -> None:
            if uid == "pad":
                rows = np.full((e - s, n), RAW_PAD, np.float32)
            else:
                gather = np.array(mm[np.array(order_mm[s:e])])
                rows = np.asarray(prep(gather))
            wr.write_raw_rows(s, rows)
            recorder.record("permute", uid, flush=wr.flush)

        if workers > 1 and len(todo_u) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(lambda t: _one_unit(*t), todo_u))
        else:
            for t in todo_u:
                _one_unit(*t)
    except BaseException:
        wr.keep_partial()          # everything recorded stays resumable
        raise

    # -- stage 5: publish (fsync + atomic rename) ------------------------
    wr.close()
    man.record_unit("publish", "0", file_record(out_path))
    report.stages["publish"].built += 1
    report.wall_s = time.perf_counter() - t0
    say(f"published {out_path} ({n_blocks} blocks, {n_series} series) "
        f"in {report.wall_s:.1f}s")
    if not keep_work:
        shutil.rmtree(work_dir, ignore_errors=True)
    return out_path, report


def pipeline_build(source, out_path: str | Path, **kw) -> BlockIndex:
    """Build (or resume) via the staged pipeline and open the result
    out-of-core — the drop-in sharded/resumable form of
    ``ooc_build.build_on_disk`` (which wraps this with one worker)."""
    path, _ = run_pipeline(source, out_path, **kw)
    return format_lib.open_index(path)
