"""Pass 1 of the build pipeline: sorted summary runs (DESIGN.md §5).

A *run* is the unit the ParIS+-style parallel bulk loader flushes: one
worker scans a contiguous shard [row_start, row_stop) of the source
``SeriesStore`` through the summarize kernel, locally sorts the shard's
summaries by the bit-interleaved iSAX word, and writes one standalone
``kind="run"`` DSIX file (format.write_arrays — atomic publish):

    keys (K, m) u4   the interleaved sort-key columns, in run order
    sax  (m, w) u2   the iSAX words, in run order
    ids  (m,)   i8   original source row ids, in run order

Runs are self-describing and independent — any subset of them can be
k-way merged (merge.py) into a global order, which is exactly the shape
the future LSM delta-compaction job needs: a delta index's summaries are
just one more run to merge against the base's.

Tie-breaking contract (the byte-identity linchpin): within a run the
local lexsort is STABLE over a shard scanned in source order, so rows
with equal keys appear in ascending source id; the merge breaks
cross-run key ties by source id as well.  Total order = (keys, id) —
identical to one global stable ``np.lexsort``, hence identical to
``isax.sort_order`` on the whole array.
"""
from __future__ import annotations

from pathlib import Path

import jax
import numpy as np

from repro.core import isax
from repro.data.loader import ChunkedLoader, IncrementalBuilder, \
    summarize_chunk
from repro.storage import format as format_lib
from repro.storage.format import SeriesStore

RUN_KIND = "run"


class SummaryBuilder(IncrementalBuilder):
    """Pass-1 worker state: IncrementalBuilder that retains summaries only.

    ``add_chunk`` runs the same znorm + summarize kernel launch, but drops
    the (device) raw and z-normed chunks on the floor and keeps the sax
    words (uint16) and interleaved sort keys (uint32) on HOST — the
    summaries-resident half of the on-disk architecture: w+16 bytes per
    series, not 4n.
    """

    def __init__(self, **kw):
        super().__init__(**kw)
        if self.card > (1 << 16):
            raise ValueError("SummaryBuilder stores sax words as uint16; "
                             f"card={self.card} does not fit")
        self._keys: list[tuple[np.ndarray, ...]] = []

    def add_chunk(self, chunk: jax.Array) -> None:
        _, sax = summarize_chunk(chunk, w=self.w, card=self.card,
                                 normalize=self.normalize)
        keys = isax.interleaved_keys(sax, self.w)
        self._sax.append(np.asarray(sax).astype(np.uint16))
        self._keys.append(tuple(np.asarray(k) for k in keys))
        self._count += chunk.shape[0]

    def finalize(self):
        raise NotImplementedError(
            "SummaryBuilder holds no raw data; use the pipeline's pass 2 "
            "(storage/pipeline/driver.py)")

    def key_columns(self) -> tuple[np.ndarray, ...]:
        """The accumulated interleaved-key columns, most significant first."""
        if not self._keys:
            raise ValueError("no chunks added")
        return tuple(np.concatenate([c[i] for c in self._keys])
                     for i in range(len(self._keys[0])))

    def sort_order(self) -> np.ndarray:
        """Block-order permutation == isax.sort_order on the full array."""
        # np.lexsort: last key is primary — same convention as jnp.lexsort
        # in isax.sort_order, and both are stable ascending.
        return np.lexsort(tuple(reversed(self.key_columns()))) \
            .astype(np.int64)

    def sax_words(self) -> np.ndarray:
        return np.concatenate(self._sax, axis=0)


def build_run(store: SeriesStore, out_path: str | Path, *,
              row_start: int, row_stop: int, w: int, card: int,
              chunk: int, normalize: bool) -> Path:
    """Scan shard rows [row_start, row_stop) and write one sorted run file.

    Streams the shard through ``ChunkedLoader`` (double-buffered disk ->
    device staging) exactly like the monolithic pass 1 did, then sorts
    LOCALLY and publishes atomically.  Thread-safe against other shards'
    workers: each run has its own loader, builder, and temp file.
    """
    m = row_stop - row_start
    if m <= 0:
        raise ValueError(f"empty shard [{row_start}, {row_stop})")
    loader = ChunkedLoader(
        lambda a, b: store.read(row_start + a, row_start + b),
        n_series=m, chunk=chunk)
    builder = SummaryBuilder(w=w, card=card, normalize=normalize)
    for dev_chunk in loader:
        builder.add_chunk(dev_chunk)
    order = builder.sort_order()                      # local, stable
    keys = builder.key_columns()
    arrays = {
        "keys": np.stack([k[order] for k in keys]).astype("<u4"),
        "sax": builder.sax_words()[order].astype("<u2"),
        "ids": (row_start + order).astype("<i8"),
    }
    return format_lib.write_arrays(
        out_path, kind=RUN_KIND, arrays=arrays,
        extra={"rows": [int(row_start), int(row_stop)], "w": w,
               "card": card})


def open_run(path: str | Path) -> tuple[dict, dict]:
    """-> (meta, {keys, sax, ids}) memmaps — streamed by the merge."""
    return format_lib.open_arrays(path, kind=RUN_KIND, mmap=True)
