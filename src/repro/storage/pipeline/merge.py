"""Stage 2 of the build pipeline: k-way external merge of sorted runs.

Produces the GLOBAL block order — the permutation the monolithic builder
got from one host lexsort — without ever materializing all summaries in
memory: each run is streamed through a small read buffer, a heap picks
the least head by (interleaved keys, source id), and the winner's
(id, sax) is appended to the output through a bounded write buffer.
Peak memory is O(buffer_rows · n_runs), independent of N.

Output is one ``kind="merge"`` DSIX file:

    sax (N, w) u2   iSAX words in global block order (pass 2 recomputes
                    per-series bounds + envelopes from these)
    ids (N,)   i8   source row ids in global block order — THE permutation

Correctness (locked by the random-shard-split property test): every run
is sorted by (keys, id) — stable local lexsort over a shard scanned in
source order — and the heap comparator is the same tuple, so the merged
sequence is sorted by (keys, id).  Since ids are unique, that total
order equals a stable global sort by keys alone: exactly
``np.lexsort`` / ``isax.sort_order`` on the full array.

The interface is deliberately source-agnostic: any set of sorted run
files merges, whatever rows they cover — the future LSM compaction job
merges a base index's summaries with delta runs through this same
function.
"""
from __future__ import annotations

import heapq
from pathlib import Path

import numpy as np

from repro.storage import format as format_lib
from repro.storage.pipeline import runs as runs_lib

MERGE_KIND = "merge"


def _run_rows(path: str | Path, buffer_rows: int):
    """Yield (key-tuple, id, sax-row) from one run file, buffered reads."""
    _, arrs = runs_lib.open_run(path)
    keys, sax, ids = arrs["keys"], arrs["sax"], arrs["ids"]
    m = ids.shape[0]
    for s in range(0, m, buffer_rows):
        e = min(s + buffer_rows, m)
        kb = np.array(keys[:, s:e])          # copy the buffer off the mmap
        sb = np.array(sax[s:e])
        ib = np.array(ids[s:e])
        for j in range(e - s):
            yield (tuple(int(x) for x in kb[:, j]), int(ib[j]), sb[j])


def merge_runs(run_paths: list[str | Path], out_path: str | Path, *,
               w: int, buffer_rows: int = 8192) -> Path:
    """K-way merge sorted runs into one global-order merge file (atomic)."""
    run_paths = [Path(p) for p in run_paths]
    n_total = sum(runs_lib.open_run(p)[0]["sections"]["ids"]["shape"][0]
                  for p in run_paths)
    specs = format_lib._generic_specs({
        "sax": ((n_total, w), "<u2"),
        "ids": ((n_total,), "<i8"),
    })
    out_path = Path(out_path)
    wr = format_lib.ArrayFileWriter(out_path, kind=MERGE_KIND, specs=specs,
                                    extra={"n_runs": len(run_paths)})
    try:
        sax_buf, ids_buf, row = [], [], 0
        streams = [_run_rows(p, buffer_rows) for p in run_paths]
        for key, sid, sax_row in heapq.merge(
                *streams, key=lambda t: (t[0], t[1])):
            sax_buf.append(sax_row)
            ids_buf.append(sid)
            if len(ids_buf) == buffer_rows:
                wr.write_rows("sax", row, np.stack(sax_buf))
                wr.write_rows("ids", row, np.asarray(ids_buf, np.int64))
                row += len(ids_buf)
                sax_buf, ids_buf = [], []
        if ids_buf:
            wr.write_rows("sax", row, np.stack(sax_buf))
            wr.write_rows("ids", row, np.asarray(ids_buf, np.int64))
            row += len(ids_buf)
        if row != n_total:
            raise ValueError(f"merge produced {row} of {n_total} rows")
    except BaseException:
        wr.abort()
        raise
    wr.close()
    return out_path


def open_merge(path: str | Path) -> tuple[dict, dict]:
    """-> (meta, {sax, ids}) memmaps — pass 2 streams slices of these."""
    return format_lib.open_arrays(path, kind=MERGE_KIND, mmap=True)


def merge_order(run_paths: list[str | Path]) -> np.ndarray:
    """The merged global permutation alone (property tests, small inputs)."""
    out = []
    for _, sid, _ in heapq.merge(*[_run_rows(Path(p), 8192)
                                   for p in run_paths],
                                 key=lambda t: (t[0], t[1])):
        out.append(sid)
    return np.asarray(out, np.int64)
