"""Persisted index file format + raw-series store (DESIGN.md §5).

The paper's on-disk systems (ParIS/ParIS+) hold only the iSAX summaries in
memory and leave the raw series on disk; queries touch raw bytes only for
the leaves that survive pruning.  This module is the serialization layer
that makes the same split possible here:

  * ``save_index`` persists a built ``BlockIndex`` into one versioned file;
  * ``load_index`` reads it back fully onto device (the in-memory paths);
  * ``open_index`` reads ONLY the summaries/envelopes/ids onto device and
    leaves the raw blocks as an ``np.memmap`` over the file — the
    out-of-core view that storage/ooc_search.py streams from.

File layout (all little-endian; one file, mmap-friendly):

    0:4    magic  b"DSIX"
    4:8    u32    format version
    8:16   u64    meta length L (bytes of UTF-8 JSON)
    16:24  u64    data_start (absolute, page-aligned)
    24:24+L       meta JSON: file kind, index meta (n, w, card, capacity,
                  n_real, n_blocks), caller ``extra`` dict, and per-section
                  {offset (relative to data_start), shape, dtype}

    data_start +  ids (B, C) i4 · slo (B, w, C) f4 · shi · elo (w, B) f4
                  · ehi — each 64-aligned — then, page-aligned and LAST,
                  raw (B, C, n) f4, so the memmap window is one contiguous
                  aligned span and appending raw during a streaming build
                  (the pipeline's pass 2) needs no backpatching.

Format v2 (this repo's second on-disk generation) adds a ``kind`` field to
the meta JSON so the SAME container carries the build pipeline's
intermediate files: ``kind="run"`` sorted summary runs and ``kind="merge"``
merged global orders (storage/pipeline/), alongside ``kind="index"``.
v1 files (no ``kind``) are still read bit-exactly: the section layout is
unchanged, so ``read_meta`` just defaults their kind to "index"
(back-compat locked by tests/test_pipeline.py).

Every writer here publishes atomically: bytes go to a temp path and
``os.replace`` onto the final name only after a full flush+fsync, so a
file that EXISTS under its final name is complete — and the readers
enforce the contrapositive, rejecting truncated/partial files (from an
interrupted copy, external truncation, or a foreign writer) loudly via
``check_complete`` instead of mmapping garbage.

``SeriesStore`` handles the other file kind in play: headerless raw-series
datasets (row-major float32 (N, n), the standard data-series benchmark
format), so builds can start from a path instead of an in-RAM array.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.core.index import BlockIndex, HostRawBlocks

MAGIC = b"DSIX"
VERSION = 2          # v2: meta "kind" field (run/merge pipeline files)
_ALIGN = 64          # section alignment
_PAGE = 4096         # raw-section (memmap window) alignment
_FIXED = 24          # bytes before the meta JSON

# Index-file section order is part of the format: raw last (see docstring).
_SECTIONS = ("ids", "slo", "shi", "elo", "ehi", "raw")


def _align(off: int, align: int) -> int:
    return (off + align - 1) // align * align


def _section_specs(*, n_blocks: int, capacity: int, w: int, n: int) -> dict:
    """name -> {offset (relative), shape, dtype} for the index layout."""
    b, c = n_blocks, capacity
    shapes = {
        "ids": ((b, c), "<i4"),
        "slo": ((b, w, c), "<f4"),
        "shi": ((b, w, c), "<f4"),
        "elo": ((w, b), "<f4"),
        "ehi": ((w, b), "<f4"),
        "raw": ((b, c, n), "<f4"),
    }
    specs, off = {}, 0
    for name in _SECTIONS:
        shape, dtype = shapes[name]
        off = _align(off, _PAGE if name == "raw" else _ALIGN)
        specs[name] = {"offset": off, "shape": list(shape), "dtype": dtype}
        off += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return specs


def _generic_specs(shapes: dict) -> dict:
    """name -> spec for a generic (run/merge) file: 64-aligned, dict order."""
    specs, off = {}, 0
    for name, (shape, dtype) in shapes.items():
        off = _align(off, _ALIGN)
        specs[name] = {"offset": off, "shape": list(shape), "dtype": dtype}
        off += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return specs


def _section_nbytes(spec: dict) -> int:
    return int(np.prod(spec["shape"])) * np.dtype(spec["dtype"]).itemsize


def data_end(meta: dict) -> int:
    """Absolute end offset of the last section — the complete file size."""
    return meta["data_start"] + max(
        s["offset"] + _section_nbytes(s) for s in meta["sections"].values())


def check_complete(path: str | Path, meta: dict) -> None:
    """Loudly reject a truncated/partial file before any section is read.

    Writers publish via write-to-temp + atomic rename, so a file under its
    final name is normally complete; a short file means an interrupted
    copy, external truncation, or a foreign writer — mmapping it would
    serve garbage (or crash later, deep in a search).
    """
    expected = data_end(meta)
    actual = os.path.getsize(path)
    if actual < expected:
        raise ValueError(
            f"{path}: truncated/partial file — {actual} bytes on disk but "
            f"the header promises {expected}.  Builds publish atomically "
            f"(temp + rename), so this file was likely produced by an "
            f"interrupted copy or external truncation; rebuild or re-copy "
            f"it.")


@sanitize.guarded
class ArrayFileWriter:
    """Incremental positioned writer for the DSIX container.

    Serves every file kind: the index itself (``IndexFileWriter``), the
    pipeline's sorted summary runs and merged order (storage/pipeline/).
    Three properties the build pipeline leans on:

      * **atomic publish** — bytes go to a temp path; ``close()`` flushes,
        fsyncs and ``os.replace``s onto the final name, so a kill mid-write
        never leaves a partial file under the final name;
      * **positioned row writes** — ``write_rows(name, start, rows)`` seeks
        to the section row, so independent units of work (pipeline permute
        units, possibly on worker threads — writes are lock-serialized)
        can fill disjoint spans in any order, and REDOING a unit rewrites
        identical bytes (idempotent resume);
      * **stable-temp resume** — with ``tmp_path=``/``resume=True`` a later
        process reopens the surviving partial (after verifying the header
        bytes match, i.e. same layout/params) and continues instead of
        restarting; ``keep_partial()`` closes the fd without publishing.
    """

    def __init__(self, path: str | Path, *, kind: str, specs: dict,
                 meta_fields: dict | None = None, extra: dict | None = None,
                 tmp_path: str | Path | None = None, resume: bool = False):
        self.path = Path(path)
        meta = {"kind": kind}
        meta.update(meta_fields or {})
        meta["extra"] = dict(extra or {})
        meta["sections"] = specs
        blob = json.dumps(meta).encode()
        self.sections = specs
        self.data_start = _align(_FIXED + len(blob), _PAGE)
        self._header = (MAGIC + struct.pack("<I", VERSION)
                        + struct.pack("<QQ", len(blob), self.data_start)
                        + blob)
        # write-to-tmp + rename publish (same property train/checkpoint.py
        # relies on): a killed build never clobbers an existing good file
        # and never leaves a partial file at the final path.  A caller that
        # wants crash-RESUME passes a stable tmp_path (the pid-salted
        # default is unfindable by the next process, by design: one-shot
        # writers must never collide).
        self._tmp = Path(tmp_path) if tmp_path is not None else \
            self.path.with_name(f".tmp-{os.getpid()}-{self.path.name}")
        self._lock = sanitize.create_lock()
        self.resumed = False
        if resume and self._tmp.exists():
            f = open(self._tmp, "r+b")
            if f.read(len(self._header)) == self._header:
                self._f, self.resumed = f, True
            else:                      # stale partial: other params/layout
                f.close()
        if not self.resumed:
            self._f = open(self._tmp, "wb")   # guarded by: _lock
            self._f.write(self._header)

    @property
    def end_offset(self) -> int:
        return self.data_start + max(
            s["offset"] + _section_nbytes(s) for s in self.sections.values())

    def write_rows(self, name: str, start: int, rows: np.ndarray) -> None:
        """Write ``rows`` at row ``start`` of section ``name`` (axis 0)."""
        spec = self.sections[name]
        shape, dtype = spec["shape"], np.dtype(spec["dtype"])
        rows = np.ascontiguousarray(rows, dtype=dtype)
        if list(rows.shape[1:]) != shape[1:]:
            raise ValueError(f"{name}: row shape {rows.shape[1:]} != "
                             f"{tuple(shape[1:])}")
        if start < 0 or start + rows.shape[0] > shape[0]:
            raise ValueError(f"{name}: rows [{start}, "
                             f"{start + rows.shape[0]}) overflow {shape[0]}")
        row_bytes = _section_nbytes(spec) // max(shape[0], 1)
        with self._lock:
            self._f.seek(self.data_start + spec["offset"] + start * row_bytes)
            self._f.write(rows.tobytes())

    def write_section(self, name: str, array: np.ndarray) -> None:
        spec = self.sections[name]
        arr = np.asarray(array)
        if list(arr.shape) != spec["shape"]:
            raise ValueError(f"{name}: shape {arr.shape} != {spec['shape']}")
        self.write_rows(name, 0, arr)

    def flush(self) -> None:
        """Push buffered bytes to the OS — a unit recorded in the build
        manifest after ``flush`` survives a SIGKILL of this process."""
        with self._lock:
            self._f.flush()

    def close(self) -> None:
        """Finalize and atomically publish under the final name."""
        with self._lock:
            # extend to the full span even if the last rows were all-zero
            # (sparse positioned writes must not shorten the file)
            self._f.truncate(self.end_offset)
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
        os.replace(self._tmp, self.path)   # atomic publish

    def keep_partial(self) -> None:
        """Close the fd but KEEP the temp file for a later resume."""
        with self._lock:
            self._f.flush()
            self._f.close()

    def abort(self) -> None:
        with self._lock:
            self._f.close()
        self._tmp.unlink(missing_ok=True)

    def __enter__(self) -> "ArrayFileWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


@sanitize.guarded
class IndexFileWriter(ArrayFileWriter):
    """Incremental writer for the index file kind.

    ``save_index`` uses it in one shot; the build pipeline
    (storage/pipeline/driver.py) uses its positioned writes to fill the
    summary sections and raw permute units — resumably, via a stable
    ``tmp_path``.  ``append_raw_rows`` keeps the simple sequential-append
    surface for one-shot writers.
    """

    def __init__(self, path: str | Path, *, n: int, w: int, card: int,
                 capacity: int, n_real: int, n_blocks: int,
                 extra: dict | None = None,
                 tmp_path: str | Path | None = None, resume: bool = False):
        self.meta = dict(n=n, w=w, card=card, capacity=capacity,
                         n_real=n_real, n_blocks=n_blocks)
        super().__init__(
            path, kind="index",
            specs=_section_specs(n_blocks=n_blocks, capacity=capacity,
                                 w=w, n=n),
            meta_fields=self.meta, extra=extra,
            tmp_path=tmp_path, resume=resume)
        self._raw_rows = 0                      # guarded by: _lock

    def write_raw_rows(self, start: int, rows: np.ndarray) -> None:
        """Write (m, n) f32 series rows at series-row ``start`` of the raw
        section — SERIES granularity, not block granularity, so permute
        units need not align to block boundaries."""
        spec = self.sections["raw"]
        b, c, n = spec["shape"]
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        if rows.ndim != 2 or rows.shape[1] != n:
            raise ValueError(f"raw rows must be (m, {n}), got {rows.shape}")
        if start < 0 or start + rows.shape[0] > b * c:
            raise ValueError("raw section overflow")
        with self._lock:
            self._f.seek(self.data_start + spec["offset"] + start * n * 4)
            self._f.write(rows.tobytes())

    def append_raw_rows(self, rows: np.ndarray) -> None:
        """Append (m, n) f32 series rows to the raw section, in block order.

        Reserve-then-write: the row counter advances under the lock
        (the lock is not reentrant, so the reservation releases before
        the positioned write re-acquires it), then the write lands in
        the reserved span — concurrent appenders get disjoint spans.
        The pre-annotation code read and bumped ``_raw_rows`` off-lock,
        which the lock checker (LOCK001) rejects: two appenders could
        reserve the same start row.
        """
        m = rows.shape[0]
        b, c, _ = self.sections["raw"]["shape"]
        with self._lock:
            if self._raw_rows + m > b * c:
                raise ValueError("raw section overflow")
            start = self._raw_rows
            self._raw_rows += m
        self.write_raw_rows(start, rows)

    def close(self) -> None:
        b, c, _ = self.sections["raw"]["shape"]
        with self._lock:
            raw_rows = self._raw_rows
        # append-mode completeness guard; positioned writers (the pipeline)
        # track completeness through their manifest instead
        if raw_rows not in (0, b * c):
            self.abort()
            raise ValueError(
                f"raw section incomplete: {raw_rows} of {b * c} rows")
        super().close()


def write_arrays(path: str | Path, *, kind: str, arrays: dict,
                 extra: dict | None = None) -> Path:
    """One-shot atomic write of a generic (run/merge) DSIX file."""
    path = Path(path)
    specs = _generic_specs({name: (arr.shape, arr.dtype.str)
                            for name, arr in arrays.items()})
    with ArrayFileWriter(path, kind=kind, specs=specs, extra=extra) as wr:
        for name, arr in arrays.items():
            wr.write_section(name, arr)
    return path


def open_arrays(path: str | Path, *, kind: str | None = None,
                mmap: bool = True) -> tuple[dict, dict]:
    """-> (meta, {section: array}) for a generic DSIX file.

    ``mmap=True`` returns read-only memmaps (the merge streams runs
    through these without materializing them); completeness is checked
    first so a partial file fails loudly, not at some later page fault.
    """
    path = Path(path)
    meta = read_meta(path)
    if kind is not None and meta["kind"] != kind:
        raise ValueError(f"{path}: kind {meta['kind']!r}, expected {kind!r}")
    check_complete(path, meta)
    out = {}
    for name, spec in meta["sections"].items():
        shape = tuple(spec["shape"])
        if mmap:
            out[name] = np.memmap(path, dtype=np.dtype(spec["dtype"]),
                                  mode="r",
                                  offset=meta["data_start"] + spec["offset"],
                                  shape=shape)
        else:
            with open(path, "rb") as f:
                out[name] = _read_section(f, meta, name)
    return meta, out


def spec_row_bytes(spec: dict) -> int:
    """Bytes of one trailing-dim row of a section (raw: one series)."""
    return spec["shape"][-1] * np.dtype(spec["dtype"]).itemsize


def read_meta(path: str | Path) -> dict:
    """Parse the header; -> meta dict (incl. 'kind', 'extra', 'sections',
    'data_start').  v1 files (pre-pipeline) carry no 'kind' field and
    default to "index" — the section layout is identical, so they load
    bit-exactly through the same readers."""
    with open(path, "rb") as f:
        head = f.read(_FIXED)
        if len(head) < _FIXED or head[:4] != MAGIC:
            raise ValueError(f"{path}: not an index file (bad magic)")
        version, = struct.unpack("<I", head[4:8])
        if version > VERSION:
            raise ValueError(f"{path}: format version {version} is newer "
                             f"than supported ({VERSION})")
        meta_len, data_start = struct.unpack("<QQ", head[8:24])
        blob = f.read(meta_len)
        if len(blob) < meta_len:
            raise ValueError(f"{path}: truncated header ({len(blob)} of "
                             f"{meta_len} meta bytes)")
        meta = json.loads(blob.decode())
    meta.setdefault("kind", "index")
    meta["version"] = version
    meta["data_start"] = data_start
    return meta


def _read_section(f, meta: dict, name: str) -> np.ndarray:
    spec = meta["sections"][name]
    f.seek(meta["data_start"] + spec["offset"])
    count = int(np.prod(spec["shape"]))
    arr = np.fromfile(f, dtype=np.dtype(spec["dtype"]), count=count)
    if arr.size != count:
        raise ValueError(f"{name}: truncated index file")
    return arr.reshape(spec["shape"])


def _read_index_meta(path: Path) -> dict:
    meta = read_meta(path)
    if meta["kind"] != "index":
        raise ValueError(
            f"{path}: this is a {meta['kind']!r} file (a build-pipeline "
            f"intermediate, storage/pipeline/), not an index")
    check_complete(path, meta)
    return meta


def save_index(index: BlockIndex, path: str | Path, *,
               extra: dict | None = None) -> Path:
    """Persist a built (device-resident) index into one file."""
    if not index.device_resident:
        raise ValueError("index is already out-of-core; nothing to save")
    path = Path(path)
    with IndexFileWriter(path, n=index.n, w=index.w, card=index.card,
                         capacity=index.capacity, n_real=index.n_real,
                         n_blocks=index.n_blocks, extra=extra) as wr:
        wr.write_section("ids", np.asarray(index.ids))
        wr.write_section("slo", np.asarray(index.slo))
        wr.write_section("shi", np.asarray(index.shi))
        wr.write_section("elo", np.asarray(index.elo))
        wr.write_section("ehi", np.asarray(index.ehi))
        wr.write_section("raw", np.asarray(index.raw))
    return path


def _load_summaries(path: Path, meta: dict) -> dict:
    with open(path, "rb") as f:
        return {name: _read_section(f, meta, name)
                for name in ("ids", "slo", "shi", "elo", "ehi")}


def load_index(path: str | Path) -> BlockIndex:
    """Full load: everything (raw included) onto device — the in-memory
    paths (`core.search`, `paris`, …) work on the result unchanged."""
    path = Path(path)
    meta = _read_index_meta(path)
    parts = _load_summaries(path, meta)
    with open(path, "rb") as f:
        raw = _read_section(f, meta, "raw")
    return BlockIndex(
        raw=jnp.asarray(raw), slo=jnp.asarray(parts["slo"]),
        shi=jnp.asarray(parts["shi"]), elo=jnp.asarray(parts["elo"]),
        ehi=jnp.asarray(parts["ehi"]), ids=jnp.asarray(parts["ids"]),
        n=meta["n"], w=meta["w"], card=meta["card"],
        capacity=meta["capacity"], n_real=meta["n_real"])


def open_index(path: str | Path) -> BlockIndex:
    """Out-of-core open: summaries/envelopes/ids to device, raw blocks left
    on disk as an ``np.memmap`` behind ``BlockIndex.host_raw``.

    Device-side HBM cost is the summary footprint only — 2·w floats per
    series + envelopes — which is what lets a dataset far larger than
    device memory be searched (storage/ooc_search.py).  ``raw`` becomes a
    zero-width (B, 0, n) placeholder; the in-memory search paths reject it
    with a pointer here (frontier.prepare).
    """
    path = Path(path)
    meta = _read_index_meta(path)
    parts = _load_summaries(path, meta)
    spec = meta["sections"]["raw"]
    mm = np.memmap(path, dtype=np.dtype(spec["dtype"]), mode="r",
                   offset=meta["data_start"] + spec["offset"],
                   shape=tuple(spec["shape"]))
    b, _, n = spec["shape"]
    return BlockIndex(
        raw=jnp.zeros((b, 0, n), jnp.float32),
        slo=jnp.asarray(parts["slo"]), shi=jnp.asarray(parts["shi"]),
        elo=jnp.asarray(parts["elo"]), ehi=jnp.asarray(parts["ehi"]),
        ids=jnp.asarray(parts["ids"]),
        n=meta["n"], w=meta["w"], card=meta["card"],
        capacity=meta["capacity"], n_real=meta["n_real"],
        host_raw=HostRawBlocks(mm, path=str(path)))


@dataclasses.dataclass
class SeriesStore:
    """A headerless raw-series file: row-major (n_series, length) float32.

    The standard interchange format of the data-series benchmarks (the
    paper's 100GB datasets ship exactly like this).  Gives builds a file
    source: ``memmap()`` for random access (the pass-2 permute),
    ``read`` for the sequential pass-1 stream (plugs into
    ``data.ChunkedLoader`` as a reader, or just pass the path — the loader
    mmaps it itself).
    """
    path: Path
    length: int
    dtype: np.dtype = np.dtype(np.float32)

    def __post_init__(self):
        self.path = Path(self.path)
        self.dtype = np.dtype(self.dtype)
        size = os.path.getsize(self.path)
        row = self.length * self.dtype.itemsize
        if row <= 0 or size % row:
            raise ValueError(
                f"{self.path}: size {size} is not a multiple of "
                f"length {self.length} x itemsize {self.dtype.itemsize}")
        self.n_series = size // row
        self._mm: np.memmap | None = None

    def __len__(self) -> int:
        return self.n_series

    @property
    def nbytes(self) -> int:
        return self.n_series * self.length * self.dtype.itemsize

    def memmap(self) -> np.memmap:
        # one mapping for the store's lifetime: ``read`` is the pass-1
        # per-chunk reader, so remapping per call would be pure syscall
        # overhead on the streaming hot path
        if self._mm is None:
            self._mm = np.memmap(self.path, dtype=self.dtype, mode="r",
                                 shape=(self.n_series, self.length))
        return self._mm

    def read(self, start: int, stop: int) -> np.ndarray:
        """Copy rows [start, stop) off disk (a ChunkedLoader reader)."""
        return np.array(self.memmap()[start:stop])

    @classmethod
    def write(cls, path: str | Path, series: np.ndarray) -> "SeriesStore":
        """Write an (N, n) array as a headerless store (tests/benchmarks)."""
        arr = np.ascontiguousarray(series, dtype=np.float32)
        if arr.ndim != 2:
            raise ValueError(f"series must be 2-D, got {arr.shape}")
        with open(path, "wb") as f:
            f.write(arr.tobytes())
        return cls(path=Path(path), length=arr.shape[1])
