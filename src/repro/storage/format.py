"""Persisted index file format + raw-series store (DESIGN.md §5).

The paper's on-disk systems (ParIS/ParIS+) hold only the iSAX summaries in
memory and leave the raw series on disk; queries touch raw bytes only for
the leaves that survive pruning.  This module is the serialization layer
that makes the same split possible here:

  * ``save_index`` persists a built ``BlockIndex`` into one versioned file;
  * ``load_index`` reads it back fully onto device (the in-memory paths);
  * ``open_index`` reads ONLY the summaries/envelopes/ids onto device and
    leaves the raw blocks as an ``np.memmap`` over the file — the
    out-of-core view that storage/ooc_search.py streams from.

File layout (all little-endian; one file, mmap-friendly):

    0:4    magic  b"DSIX"
    4:8    u32    format version
    8:16   u64    meta length L (bytes of UTF-8 JSON)
    16:24  u64    data_start (absolute, page-aligned)
    24:24+L       meta JSON: index meta (n, w, card, capacity, n_real,
                  n_blocks), caller ``extra`` dict, and per-section
                  {offset (relative to data_start), shape, dtype}

    data_start +  ids (B, C) i4 · slo (B, w, C) f4 · shi · elo (w, B) f4
                  · ehi — each 64-aligned — then, page-aligned and LAST,
                  raw (B, C, n) f4, so the memmap window is one contiguous
                  aligned span and appending raw during a streaming build
                  (ooc_build.IndexFileWriter) needs no backpatching.

``SeriesStore`` handles the other file kind in play: headerless raw-series
datasets (row-major float32 (N, n), the standard data-series benchmark
format), so builds can start from a path instead of an in-RAM array.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex, HostRawBlocks

MAGIC = b"DSIX"
VERSION = 1
_ALIGN = 64          # section alignment
_PAGE = 4096         # raw-section (memmap window) alignment
_FIXED = 24          # bytes before the meta JSON

# Section order is part of the format: raw last (see module docstring).
_SECTIONS = ("ids", "slo", "shi", "elo", "ehi", "raw")


def _align(off: int, align: int) -> int:
    return (off + align - 1) // align * align


def _section_specs(*, n_blocks: int, capacity: int, w: int, n: int) -> dict:
    """name -> {offset (relative), shape, dtype} for the fixed layout."""
    b, c = n_blocks, capacity
    shapes = {
        "ids": ((b, c), "<i4"),
        "slo": ((b, w, c), "<f4"),
        "shi": ((b, w, c), "<f4"),
        "elo": ((w, b), "<f4"),
        "ehi": ((w, b), "<f4"),
        "raw": ((b, c, n), "<f4"),
    }
    specs, off = {}, 0
    for name in _SECTIONS:
        shape, dtype = shapes[name]
        off = _align(off, _PAGE if name == "raw" else _ALIGN)
        specs[name] = {"offset": off, "shape": list(shape), "dtype": dtype}
        off += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return specs


def _build_meta(index_meta: dict, extra: dict | None) -> tuple[bytes, int]:
    """-> (meta JSON bytes, absolute data_start)."""
    specs = _section_specs(
        n_blocks=index_meta["n_blocks"], capacity=index_meta["capacity"],
        w=index_meta["w"], n=index_meta["n"])
    meta = dict(index_meta)
    meta["extra"] = dict(extra or {})
    meta["sections"] = specs
    blob = json.dumps(meta).encode()
    return blob, _align(_FIXED + len(blob), _PAGE)


class IndexFileWriter:
    """Incremental writer for the index file format.

    ``save_index`` uses it in one shot; the out-of-core builder
    (storage/ooc_build.py) uses it to append raw blocks as they are
    permuted off the source file, never holding them all at once.
    """

    def __init__(self, path: str | Path, *, n: int, w: int, card: int,
                 capacity: int, n_real: int, n_blocks: int,
                 extra: dict | None = None):
        self.path = Path(path)
        self.meta = dict(n=n, w=w, card=card, capacity=capacity,
                         n_real=n_real, n_blocks=n_blocks)
        blob, data_start = _build_meta(self.meta, extra)
        self.sections = json.loads(blob)["sections"]
        self.data_start = data_start
        self._raw_rows = 0
        # write-to-tmp + rename publish (same property train/checkpoint.py
        # relies on): a killed build never clobbers an existing good index
        # and never leaves a partial file at the final path
        self._tmp = self.path.with_name(
            f".tmp-{os.getpid()}-{self.path.name}")
        self._f = open(self._tmp, "wb")
        self._f.write(MAGIC)
        self._f.write(struct.pack("<I", VERSION))
        self._f.write(struct.pack("<QQ", len(blob), data_start))
        self._f.write(blob)

    def write_section(self, name: str, array: np.ndarray) -> None:
        spec = self.sections[name]
        arr = np.ascontiguousarray(array, dtype=np.dtype(spec["dtype"]))
        if list(arr.shape) != spec["shape"]:
            raise ValueError(f"{name}: shape {arr.shape} != {spec['shape']}")
        self._f.seek(self.data_start + spec["offset"])
        self._f.write(arr.tobytes())

    def append_raw_rows(self, rows: np.ndarray) -> None:
        """Append (m, n) f32 series rows to the raw section, in block order."""
        spec = self.sections["raw"]
        b, c, n = spec["shape"]
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        if rows.ndim != 2 or rows.shape[1] != n:
            raise ValueError(f"raw rows must be (m, {n}), got {rows.shape}")
        if self._raw_rows + rows.shape[0] > b * c:
            raise ValueError("raw section overflow")
        self._f.seek(self.data_start + spec["offset"]
                     + self._raw_rows * n * 4)
        self._f.write(rows.tobytes())
        self._raw_rows += rows.shape[0]

    def close(self) -> None:
        spec = self.sections["raw"]
        b, c, _ = spec["shape"]
        if self._raw_rows not in (0, b * c):
            self.abort()
            raise ValueError(
                f"raw section incomplete: {self._raw_rows} of {b * c} rows")
        # ensure the file extends to the full raw span even if the last
        # rows were all-zero (sparse writes must not shorten the file)
        end = self.data_start + spec["offset"] + b * c * spec_row_bytes(spec)
        self._f.truncate(end)
        self._f.close()
        os.replace(self._tmp, self.path)   # atomic publish

    def abort(self) -> None:
        self._f.close()
        self._tmp.unlink(missing_ok=True)

    def __enter__(self) -> "IndexFileWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


def spec_row_bytes(spec: dict) -> int:
    """Bytes of one trailing-dim row of a section (raw: one series)."""
    return spec["shape"][-1] * np.dtype(spec["dtype"]).itemsize


def read_meta(path: str | Path) -> dict:
    """Parse the header; -> meta dict (incl. 'extra', 'sections',
    'data_start')."""
    with open(path, "rb") as f:
        head = f.read(_FIXED)
        if len(head) < _FIXED or head[:4] != MAGIC:
            raise ValueError(f"{path}: not an index file (bad magic)")
        version, = struct.unpack("<I", head[4:8])
        if version > VERSION:
            raise ValueError(f"{path}: format version {version} is newer "
                             f"than supported ({VERSION})")
        meta_len, data_start = struct.unpack("<QQ", head[8:24])
        meta = json.loads(f.read(meta_len).decode())
    meta["version"] = version
    meta["data_start"] = data_start
    return meta


def _read_section(f, meta: dict, name: str) -> np.ndarray:
    spec = meta["sections"][name]
    f.seek(meta["data_start"] + spec["offset"])
    count = int(np.prod(spec["shape"]))
    arr = np.fromfile(f, dtype=np.dtype(spec["dtype"]), count=count)
    if arr.size != count:
        raise ValueError(f"{name}: truncated index file")
    return arr.reshape(spec["shape"])


def save_index(index: BlockIndex, path: str | Path, *,
               extra: dict | None = None) -> Path:
    """Persist a built (device-resident) index into one file."""
    if not index.device_resident:
        raise ValueError("index is already out-of-core; nothing to save")
    path = Path(path)
    with IndexFileWriter(path, n=index.n, w=index.w, card=index.card,
                         capacity=index.capacity, n_real=index.n_real,
                         n_blocks=index.n_blocks, extra=extra) as wr:
        wr.write_section("ids", np.asarray(index.ids))
        wr.write_section("slo", np.asarray(index.slo))
        wr.write_section("shi", np.asarray(index.shi))
        wr.write_section("elo", np.asarray(index.elo))
        wr.write_section("ehi", np.asarray(index.ehi))
        wr.write_section("raw", np.asarray(index.raw))
    return path


def _load_summaries(path: Path, meta: dict) -> dict:
    with open(path, "rb") as f:
        return {name: _read_section(f, meta, name)
                for name in ("ids", "slo", "shi", "elo", "ehi")}


def load_index(path: str | Path) -> BlockIndex:
    """Full load: everything (raw included) onto device — the in-memory
    paths (`core.search`, `paris`, …) work on the result unchanged."""
    path = Path(path)
    meta = read_meta(path)
    parts = _load_summaries(path, meta)
    with open(path, "rb") as f:
        raw = _read_section(f, meta, "raw")
    return BlockIndex(
        raw=jnp.asarray(raw), slo=jnp.asarray(parts["slo"]),
        shi=jnp.asarray(parts["shi"]), elo=jnp.asarray(parts["elo"]),
        ehi=jnp.asarray(parts["ehi"]), ids=jnp.asarray(parts["ids"]),
        n=meta["n"], w=meta["w"], card=meta["card"],
        capacity=meta["capacity"], n_real=meta["n_real"])


def open_index(path: str | Path) -> BlockIndex:
    """Out-of-core open: summaries/envelopes/ids to device, raw blocks left
    on disk as an ``np.memmap`` behind ``BlockIndex.host_raw``.

    Device-side HBM cost is the summary footprint only — 2·w floats per
    series + envelopes — which is what lets a dataset far larger than
    device memory be searched (storage/ooc_search.py).  ``raw`` becomes a
    zero-width (B, 0, n) placeholder; the in-memory search paths reject it
    with a pointer here (frontier.prepare).
    """
    path = Path(path)
    meta = read_meta(path)
    parts = _load_summaries(path, meta)
    spec = meta["sections"]["raw"]
    mm = np.memmap(path, dtype=np.dtype(spec["dtype"]), mode="r",
                   offset=meta["data_start"] + spec["offset"],
                   shape=tuple(spec["shape"]))
    b, _, n = spec["shape"]
    return BlockIndex(
        raw=jnp.zeros((b, 0, n), jnp.float32),
        slo=jnp.asarray(parts["slo"]), shi=jnp.asarray(parts["shi"]),
        elo=jnp.asarray(parts["elo"]), ehi=jnp.asarray(parts["ehi"]),
        ids=jnp.asarray(parts["ids"]),
        n=meta["n"], w=meta["w"], card=meta["card"],
        capacity=meta["capacity"], n_real=meta["n_real"],
        host_raw=HostRawBlocks(mm, path=str(path)))


@dataclasses.dataclass
class SeriesStore:
    """A headerless raw-series file: row-major (n_series, length) float32.

    The standard interchange format of the data-series benchmarks (the
    paper's 100GB datasets ship exactly like this).  Gives builds a file
    source: ``memmap()`` for random access (the pass-2 permute),
    ``read`` for the sequential pass-1 stream (plugs into
    ``data.ChunkedLoader`` as a reader, or just pass the path — the loader
    mmaps it itself).
    """
    path: Path
    length: int
    dtype: np.dtype = np.dtype(np.float32)

    def __post_init__(self):
        self.path = Path(self.path)
        self.dtype = np.dtype(self.dtype)
        size = os.path.getsize(self.path)
        row = self.length * self.dtype.itemsize
        if row <= 0 or size % row:
            raise ValueError(
                f"{self.path}: size {size} is not a multiple of "
                f"length {self.length} x itemsize {self.dtype.itemsize}")
        self.n_series = size // row
        self._mm: np.memmap | None = None

    def __len__(self) -> int:
        return self.n_series

    @property
    def nbytes(self) -> int:
        return self.n_series * self.length * self.dtype.itemsize

    def memmap(self) -> np.memmap:
        # one mapping for the store's lifetime: ``read`` is the pass-1
        # per-chunk reader, so remapping per call would be pure syscall
        # overhead on the streaming hot path
        if self._mm is None:
            self._mm = np.memmap(self.path, dtype=self.dtype, mode="r",
                                 shape=(self.n_series, self.length))
        return self._mm

    def read(self, start: int, stop: int) -> np.ndarray:
        """Copy rows [start, stop) off disk (a ChunkedLoader reader)."""
        return np.array(self.memmap()[start:stop])

    @classmethod
    def write(cls, path: str | Path, series: np.ndarray) -> "SeriesStore":
        """Write an (N, n) array as a headerless store (tests/benchmarks)."""
        arr = np.ascontiguousarray(series, dtype=np.float32)
        if arr.ndim != 2:
            raise ValueError(f"series must be 2-D, got {arr.shape}")
        with open(path, "wb") as f:
            f.write(arr.tobytes())
        return cls(path=Path(path), length=arr.shape[1])
