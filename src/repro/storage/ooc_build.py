"""Out-of-core index build: series file -> index file (DESIGN.md §5).

Since the pipeline rework this module is a thin compatibility wrapper:
the actual build path — parallel pass-1 workers emitting sorted summary
runs, a k-way external merge producing the global block order, and the
pass-2 permute streaming raw series into the final file, all resumable
from a JSON manifest — lives in ``storage/pipeline/``.
``build_on_disk`` drives it in the original monolithic shape (one
worker, one shard), and its contract is unchanged: the produced file is
byte-identical to ``save_index(core.build(...))`` on the same data
(tested), so ``load_index``/``open_index``/``ooc_search`` cannot tell
which builder wrote it.  Callers that want shards, workers, or
kill-resume call ``storage.pipeline_build``/``storage.run_pipeline``
directly.

``SummaryBuilder`` (the pass-1 summaries-only worker state) moved to
``storage/pipeline/runs.py`` and is re-exported here for the original
import path.
"""
from __future__ import annotations

from pathlib import Path

from repro.core import isax
from repro.core.index import BlockIndex
from repro.storage.pipeline.driver import pipeline_build
from repro.storage.pipeline.runs import SummaryBuilder  # noqa: F401 (compat)

__all__ = ["build_on_disk", "SummaryBuilder"]


def build_on_disk(source, out_path: str | Path, *, length: int | None = None,
                  w: int = isax.W, card: int = isax.CARD, capacity: int = 512,
                  chunk: int = 1 << 14, normalize: bool = True,
                  extra: dict | None = None) -> BlockIndex:
    """Build a persisted index from a series file, out of core.

    ``source``: a ``SeriesStore``, or a path to a headerless float32 file
    (then ``length`` is required).  Returns the index re-opened out-of-core
    (``format.open_index``) — hand it to ``storage.ooc_search``, or
    ``load_index(out_path)`` for the in-memory paths.
    """
    return pipeline_build(source, out_path, length=length, w=w, card=card,
                          capacity=capacity, chunk=chunk,
                          normalize=normalize, extra=extra,
                          workers=1, shards=1)
