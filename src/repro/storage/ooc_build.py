"""Two-pass out-of-core index build: series file -> index file (DESIGN.md §5).

The ParIS+ bulk loader never holds the dataset: it streams raw series
through the summarization workers and keeps only the iSAX summaries
resident.  Same here, with the roles TPU-cast:

  pass 1  stream the source file chunk-by-chunk through the Pallas
          summarize kernel (``ChunkedLoader``'s double buffer overlaps the
          disk read / host->device DMA with the previous chunk's compute)
          and keep ONLY the sax words + interleaved sort keys on host —
          w+16 bytes per series, not 4n;
  sort    one host lexsort over the accumulated keys — identical
          permutation to ``isax.sort_order`` on the full array (same keys,
          both sorts stable ascending);
  pass 2  walk the blocks in index order, gather each block's member rows
          off the source ``np.memmap`` (the external permute: random reads,
          sequential writes), z-normalize on device, and append straight to
          the index file's raw section via ``format.IndexFileWriter``.
          Summaries/envelopes are recomputed from the resident sax words
          with exactly ``index.assemble_blocks``'s padding/sentinel rules.

Peak host memory: O(N·(w+20)) for summaries/keys/order + one block group
of raw rows — a 100GB raw file with w=16, n=256 needs ~3.5% of its size in
RAM.  The produced file is bit-compatible with ``save_index(build(...))``
on the same data (tested), so ``load_index``/``open_index``/``ooc_search``
cannot tell which builder wrote it.
"""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import isax
from repro.core.index import RAW_PAD, BlockIndex
from repro.data.loader import ChunkedLoader, IncrementalBuilder
from repro.kernels import ops
from repro.storage import format as format_lib
from repro.storage.format import IndexFileWriter, SeriesStore


class SummaryBuilder(IncrementalBuilder):
    """Pass-1 worker: IncrementalBuilder that retains summaries only.

    ``add_chunk`` runs the same znorm + summarize kernel launch, but drops
    the (device) raw and z-normed chunks on the floor and keeps the sax
    words (uint16) and interleaved sort keys (uint32) on HOST — the
    summaries-resident half of the on-disk architecture.
    """

    def __init__(self, **kw):
        super().__init__(**kw)
        if self.card > (1 << 16):
            raise ValueError("SummaryBuilder stores sax words as uint16; "
                             f"card={self.card} does not fit")
        self._keys: list[tuple[np.ndarray, ...]] = []

    def add_chunk(self, chunk: jax.Array) -> None:
        xn = isax.znorm(chunk) if self.normalize else chunk.astype(jnp.float32)
        _, sax = ops.summarize(xn, w=self.w, card=self.card, normalize=False)
        keys = isax.interleaved_keys(sax, self.w)
        self._sax.append(np.asarray(sax).astype(np.uint16))
        self._keys.append(tuple(np.asarray(k) for k in keys))
        self._count += chunk.shape[0]

    def finalize(self):
        raise NotImplementedError(
            "SummaryBuilder holds no raw data; use build_on_disk's pass 2")

    def sort_order(self) -> np.ndarray:
        """Block-order permutation == isax.sort_order on the full array."""
        if not self._keys:
            raise ValueError("no chunks added")
        keys = tuple(np.concatenate([c[i] for c in self._keys])
                     for i in range(len(self._keys[0])))
        # np.lexsort: last key is primary — same convention as jnp.lexsort
        # in isax.sort_order, and both are stable ascending.
        return np.lexsort(tuple(reversed(keys))).astype(np.int64)

    def sax_words(self) -> np.ndarray:
        return np.concatenate(self._sax, axis=0)


def _host_bounds(sax: np.ndarray, card: int) -> tuple[np.ndarray, np.ndarray]:
    """(m, w) sax -> (m, w) lo / hi region edges — isax.region_tables lookup."""
    lo_t, hi_t = isax.region_tables(card)
    return lo_t[sax], hi_t[sax]


def build_on_disk(source, out_path: str | Path, *, length: int | None = None,
                  w: int = isax.W, card: int = isax.CARD, capacity: int = 512,
                  chunk: int = 1 << 14, normalize: bool = True,
                  extra: dict | None = None) -> BlockIndex:
    """Build a persisted index from a series file, out of core.

    ``source``: a ``SeriesStore``, or a path to a headerless float32 file
    (then ``length`` is required).  Returns the index re-opened out-of-core
    (``format.open_index``) — hand it to ``storage.ooc_search``, or
    ``load_index(out_path)`` for the in-memory paths.
    """
    store = source if isinstance(source, SeriesStore) else \
        SeriesStore(path=Path(source), length=length)
    n_series, n = store.n_series, store.length

    # -- pass 1: stream the file through the summarize kernel ------------
    loader = ChunkedLoader(store.path, chunk=chunk, length=store.length,
                           dtype=store.dtype)
    builder = SummaryBuilder(w=w, card=card, capacity=capacity,
                             normalize=normalize)
    for dev_chunk in loader:
        builder.add_chunk(dev_chunk)
    order = builder.sort_order()
    sax = builder.sax_words()

    # -- layout: same padding rules as index.assemble_blocks -------------
    cap = min(capacity, n_series)
    n_padded = n_series + (-n_series) % cap
    n_blocks = n_padded // cap

    # -- summaries in block order (host; w-sized, not n-sized) -----------
    ids = np.full((n_padded,), -1, np.int32)
    ids[:n_series] = order                       # build() sorts arange(N)
    lo = np.full((n_padded, w), isax.SENTINEL, np.float32)
    hi = np.full((n_padded, w), isax.SENTINEL, np.float32)
    lo[:n_series], hi[:n_series] = _host_bounds(sax[order], card)
    ids_b = ids.reshape(n_blocks, cap)
    slo = np.transpose(lo.reshape(n_blocks, cap, w), (0, 2, 1))  # (B, w, C)
    shi = np.transpose(hi.reshape(n_blocks, cap, w), (0, 2, 1))
    elo, ehi = index_lib.block_envelopes(slo, shi, ids_b, xp=np)
    elo, ehi = elo.astype(np.float32), ehi.astype(np.float32)

    # -- pass 2: external permute of the raw file into block order -------
    mm = store.memmap()
    prep = jax.jit(isax.znorm) if normalize else \
        jax.jit(lambda x: x.astype(jnp.float32))
    rows_per_step = max(1, (max(chunk, cap) // cap)) * cap
    with IndexFileWriter(out_path, n=n, w=w, card=card, capacity=cap,
                         n_real=n_series, n_blocks=n_blocks,
                         extra=extra) as wr:
        wr.write_section("ids", ids_b)
        wr.write_section("slo", slo)
        wr.write_section("shi", shi)
        wr.write_section("elo", elo)
        wr.write_section("ehi", ehi)
        for start in range(0, n_series, rows_per_step):
            stop = min(start + rows_per_step, n_series)
            rows = np.array(mm[order[start:stop]])   # gather (random reads)
            wr.append_raw_rows(np.asarray(prep(rows)))
        if n_padded > n_series:
            wr.append_raw_rows(np.full((n_padded - n_series, n),
                                       RAW_PAD, np.float32))
    return format_lib.open_index(out_path)
