"""On-disk index subsystem (DESIGN.md §5): persisted index format,
staged/sharded/resumable build pipeline, streaming exact k-NN search,
and the block-cache serving sessions."""
from repro.storage.cache import BlockCache, PreparedRound, SearchSession
from repro.storage.format import (SeriesStore, load_index, open_index,
                                  read_meta, save_index)
from repro.storage.ooc_build import SummaryBuilder, build_on_disk
from repro.storage.ooc_search import IOStats, OocSearchResult, ooc_search
from repro.storage.pipeline import (BuildInterrupted, BuildReport,
                                    pipeline_build, run_pipeline)

__all__ = [
    "SeriesStore", "save_index", "load_index", "open_index", "read_meta",
    "build_on_disk", "SummaryBuilder",
    "pipeline_build", "run_pipeline", "BuildReport", "BuildInterrupted",
    "ooc_search", "OocSearchResult", "IOStats",
    "BlockCache", "SearchSession", "PreparedRound",
]
