"""On-disk index subsystem (DESIGN.md §5): persisted index format,
two-pass out-of-core build, streaming exact k-NN search, and the
block-cache serving sessions."""
from repro.storage.cache import BlockCache, PreparedRound, SearchSession
from repro.storage.format import (SeriesStore, load_index, open_index,
                                  read_meta, save_index)
from repro.storage.ooc_build import SummaryBuilder, build_on_disk
from repro.storage.ooc_search import IOStats, OocSearchResult, ooc_search

__all__ = [
    "SeriesStore", "save_index", "load_index", "open_index", "read_meta",
    "build_on_disk", "SummaryBuilder",
    "ooc_search", "OocSearchResult", "IOStats",
    "BlockCache", "SearchSession", "PreparedRound",
]
