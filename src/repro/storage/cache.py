"""Device-resident LRU block cache + stateful serving sessions (DESIGN.md §5).

The paper's serving claim is two-sided: ParIS+ answers from disk in
seconds by overlapping I/O with compute, MESSI answers from memory in
milliseconds by assuming a hot working set.  A serving process sits
between the two: the dataset does not fit on device, but query traffic
is repeated, so the blocks that keep surviving pruning ARE a working
set.  This module makes that working set explicit:

  * ``BlockCache`` — a capacity-bounded LRU of device-resident raw
    blocks, keyed by *block id*.  All fetching and prefetching go
    through it: a speculative read lands in the cache under its id, so
    a block whose schedule slot is pruned before its turn simply waits
    there for a later query (or batch) instead of leaking a device
    buffer behind a stale slot key.  Reads run on a pool of ``readers``
    background threads with a bounded in-flight speculation set, so a
    depth-D pipelined walk keeps D disk reads genuinely concurrent with
    the device compute (and the per-group threshold sync) — the driver
    thread never blocks inside ``np.ascontiguousarray``.

  * ``SearchSession`` — a stateful wrapper holding one ``BlockCache``
    across query batches.  The walk itself is ``engine.run_cached``:
    the same block-major schedule as the device backend, driven through
    this session's fetch/speculate callbacks — which makes the session
    metric-generic: ``search(qs, metric=DTW(r))`` is out-of-core DTW,
    ``search(qs, metric=Cosine())`` serves embeddings, and
    ``initial_threshold`` seeds the pruning bound for the distributed
    out-of-core protocol (core/distributed.py).  Batch t+1 re-reads
    from disk only the surviving blocks that batch t (and the LRU
    horizon before it) did not already pull in; repeated traffic
    converges to MESSI's in-memory behaviour without ever holding more
    than ``cache_blocks`` raw blocks on device.

Accounting is per batch and split so the paper's pruning claim stays
measurable under caching: ``IOStats.bytes_read``/``blocks_fetched``
count actual disk reads only (each block at most once per batch — a
second same-batch read could only come from an evict-refetch cycle,
which the ``pipeline_depth + group_blocks`` capacity floor plus the
bounded in-flight set rule out), while ``IOStats.cache_hits`` counts
surviving blocks served from the cache with zero disk traffic.  A two-round protocol run is ONE
billing unit: ``approximate_threshold`` returns a ``PreparedRound``
owning round 1's touch-set and disk reads, and the round-2
``search(..., prepared=...)`` that consumes it resumes that touch-set
(first touch of a block decides hit vs miss once per protocol run) and
bills the carried reads — so a block is never both fetched in round 1
and re-counted as a warm hit in round 2, and an abandoned round 1 can
never pollute a later, unrelated batch's bill.

``storage.ooc_search`` is the one-shot form: a throwaway session with a
small cache, preserving the streaming memory profile of a single batch.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.core import engine
from repro.core import frontier as frontier_lib
from repro.core.index import BlockIndex, HostRawBlocks
from repro.storage.ooc_search import IOStats, OocSearchResult


@sanitize.guarded
class BlockCache:
    """Capacity-bounded LRU of device-resident raw blocks, keyed by block id.

    A pool of ``readers`` background reader threads serves
    ``prefetch``/``get`` misses in request order, so a depth-D pipelined
    walk keeps D disk reads genuinely concurrent (the ParIS+ shape:
    whole thread groups devoted to I/O while compute proceeds); a
    completed read inserts itself into the LRU under the lock, so an
    in-flight block can never be orphaned — whoever requested it (or
    nobody: a pruned speculation) finds it cached.  Eviction just drops
    the reference; the device buffer is freed when the last
    ``jax.Array`` reference dies.

    Speculative reads are *bounded*: ``prefetch`` declines (a silent
    no-op) once ``max_inflight`` reads are outstanding, so a deep or
    buggy speculator can never queue unbounded I/O or churn the LRU —
    demand ``get`` misses are never declined.  Dropping a speculation is
    always safe: it is a pure overlap hint, and the demand fetch that
    actually needs the block submits its own read.

    ``disk_blocks``/``disk_bytes`` are cumulative actual-disk-read
    counters (sessions snapshot deltas per batch); a cache hit moves
    none of them.  ``demand_misses`` counts ``get`` calls that found
    their block neither resident nor in flight — the walk stalls the
    pipeline was supposed to hide (``bench_serve.py`` reports the
    fraction as reader-pool effectiveness).
    """

    def __init__(self, host: HostRawBlocks, capacity_blocks: int, *,
                 readers: int = 2, max_inflight: int | None = None):
        if capacity_blocks < 2:
            # the streaming walk keeps one block in refinement plus one
            # outstanding prefetch; below 2 the prefetch could evict the
            # block it was meant to overlap, forcing a same-batch re-read
            # (a pipelined session raises the floor to depth + group —
            # see SearchSession)
            raise ValueError(
                f"capacity_blocks must be >= 2, got {capacity_blocks}")
        if readers < 1:
            raise ValueError(f"readers must be >= 1, got {readers}")
        if max_inflight is None:
            max_inflight = 2 * readers
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.host = host
        self.capacity_blocks = capacity_blocks
        self.readers = readers
        self.max_inflight = max_inflight
        self._closed = False                       # guarded by: _lock
        self._lru: OrderedDict[int, jax.Array] = (  # guarded by: _lock
            OrderedDict())
        self._inflight: dict[int, Future] = {}     # guarded by: _lock
        self._lock = sanitize.create_lock()
        self._reader = ThreadPoolExecutor(readers,
                                          thread_name_prefix="block-read")
        self.disk_blocks = 0                       # guarded by: _lock
        self.disk_bytes = 0                        # guarded by: _lock
        self.demand_misses = 0                     # guarded by: _lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def __contains__(self, block_id: int) -> bool:
        """Resident or in flight — either way no new disk read is needed."""
        with self._lock:
            return block_id in self._lru or block_id in self._inflight

    def _read(self, block_id: int) -> jax.Array:
        """Reader-thread body: disk -> host copy -> device, then publish."""
        try:
            dev = jax.device_put(self.host.fetch(block_id))
        except BaseException:
            # a failed read must not poison the cache: drop the in-flight
            # entry so the block no longer looks present and the next
            # request retries; whoever is waiting on this future still
            # sees the exception
            with self._lock:
                self._inflight.pop(block_id, None)
            raise
        with self._lock:
            self.disk_blocks += 1
            self.disk_bytes += self.host.block_nbytes
            if self._inflight.pop(block_id, None) is not None:
                self._insert(block_id, dev)
        return dev

    def _insert(self, block_id: int, dev: jax.Array) -> None:
        # caller holds self._lock
        self._lru[block_id] = dev
        while len(self._lru) > self.capacity_blocks:
            self._lru.popitem(last=False)

    def prefetch(self, block_id: int) -> None:
        """Start reading ``block_id`` in the background; no-op if present,
        in flight, at the ``max_inflight`` bound, or after ``close``."""
        with self._lock:
            if self._closed:
                return                   # a late speculation is droppable
            if block_id in self._lru:
                self._lru.move_to_end(block_id)
                return
            if (block_id not in self._inflight
                    and len(self._inflight) < self.max_inflight):
                self._inflight[block_id] = self._reader.submit(
                    self._read, block_id)

    def get(self, block_id: int) -> jax.Array:
        """The (C, n) device block; blocks only if a disk read is needed."""
        with self._lock:
            if self._closed:
                raise ValueError("BlockCache is closed")
            dev = self._lru.get(block_id)
            if dev is not None:
                self._lru.move_to_end(block_id)
                return dev
            fut = self._inflight.get(block_id)
            if fut is None:
                # a demand miss is never declined (the walk needs this
                # block NOW) — and is exactly a pipeline stall: nothing
                # had speculated the read ahead of the fetch
                self.demand_misses += 1
                fut = self._reader.submit(self._read, block_id)
                self._inflight[block_id] = fut
        return fut.result()

    def drain(self) -> None:
        """Wait for every in-flight read to land (settles the counters).

        The reader pool may hold many concurrent reads (depth-D
        speculation): each drain round snapshots ALL outstanding futures
        and waits them out, looping in case a racing ``prefetch``
        submitted more while we waited.  A failed read is swallowed
        here: it was speculative (nobody blocked on it), read no bytes,
        and removed its own in-flight entry — a caller that actually
        needs the block will ``get`` it again and either succeed or see
        the error itself.
        """
        while True:
            with self._lock:
                futs = list(self._inflight.values())
            if not futs:
                return
            for f in futs:
                try:
                    f.result()
                except Exception:
                    pass

    def clear(self) -> None:
        self.drain()
        with self._lock:
            self._lru.clear()

    def close(self) -> None:
        """Stop the readers and drop every cached block (idempotent, and
        safe with reads still in flight: outstanding reads finish and
        publish, the pool shuts down, THEN the LRU drops — so no reader
        thread can resurrect an entry after the clear, and the disk
        counters settle to exactly the reads performed)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True          # new prefetches decline from here
        self.drain()
        self._reader.shutdown(wait=True)
        with self._lock:
            self._lru.clear()


class PreparedRound:
    """Round-1 state plus its bill, scoped to one protocol run.

    Returned by ``SearchSession.approximate_threshold`` and consumed by
    exactly one ``SearchSession.search(..., prepared=...)`` on the SAME
    session.  Holds the engine's resumable ``PreparedSearch`` (frontier,
    block ranking, refined-block set, accrued stats) together with the
    session-side accounting round 1 accrued: the disk reads to carry
    into the consuming batch's ``IOStats`` and the protocol run's
    touch-set (first touch of a block decides hit vs miss exactly once
    per run).  If round 2 never runs, the object is simply dropped —
    its reads are never billed to an unrelated later batch.

    ``np.asarray(prepared)`` (and hence ``np.minimum.reduce`` over
    shards) yields the (Q,) squared k-th-best threshold.
    """

    def __init__(self, session: "SearchSession", plan, qsig,
                 state, carry_blocks: int, carry_bytes: int,
                 touched: set, hits: int):
        self.session = session
        self.plan = plan
        self.qsig = qsig
        self.state = state                   # engine.PreparedSearch
        self.carry_blocks = carry_blocks
        self.carry_bytes = carry_bytes
        self.touched = touched
        self.hits = hits
        self.consumed = False
        self.threshold = np.asarray(state.front.threshold())   # (Q,)

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self.threshold, dtype=dtype)


def _query_signature(queries) -> tuple:
    """Cheap content fingerprint binding a PreparedRound to its batch."""
    q = np.asarray(queries)
    return (q.shape, str(q.dtype), hash(q.tobytes()))


class _TouchTracker:
    """One accounting unit's fetch/speculate callbacks over a cache.

    The first touch of each block id decides hit vs miss exactly once
    per unit — later touches of the same block (a ``get`` after its own
    prefetch, or another tenant of a coalesced drain needing the same
    block) count nothing.  A resumed round 2 constructs the tracker
    from round 1's carried touch-set, continuing the same unit.
    """

    def __init__(self, cache: BlockCache, touched: set | None = None,
                 hits: int = 0):
        self.cache = cache
        self.touched = set() if touched is None else touched
        self.hits = hits
        # snapshot the disk counters so the unit's deltas are its own
        self._reads0 = cache.disk_blocks
        self._bytes0 = cache.disk_bytes

    def _touch(self, b: int) -> None:
        if b not in self.touched:
            self.touched.add(b)
            if b in self.cache:
                self.hits += 1

    def fetch(self, b: int) -> jax.Array:
        self._touch(b)
        return self.cache.get(b)

    def speculate(self, b: int) -> None:
        self._touch(b)
        self.cache.prefetch(b)

    @property
    def disk_blocks(self) -> int:
        return self.cache.disk_blocks - self._reads0

    @property
    def disk_bytes(self) -> int:
        return self.cache.disk_bytes - self._bytes0


@sanitize.guarded
class SearchSession:
    """Stateful out-of-core serving: one block cache across query batches.

    >>> sess = SearchSession(storage.open_index(path), cache_blocks=64)
    >>> r1 = sess.search(queries, k=5)          # cold: disk reads
    >>> r2 = sess.search(queries, k=5)          # warm: cache hits
    >>> assert r2.io.bytes_read == 0            # when all survivors fit

    Results are bit-identical to ``ooc_search`` on the same index and
    queries — the cache changes what is read, never what is answered.
    Cumulative ``cache_hits``/``blocks_fetched``/``hit_rate`` summarize
    the session; each result's ``io`` carries the per-batch split.
    """

    def __init__(self, index: BlockIndex, *, cache_blocks: int = 64,
                 readers: int = 2, pipeline_depth: int = 1,
                 group_blocks: int = 1):
        if index.host_raw is None:
            raise ValueError("index has no host_raw — open it with "
                             "storage.open_index (or pass a built index to "
                             "core.search instead)")
        if pipeline_depth < 1 or group_blocks < 1:
            raise ValueError(
                f"pipeline_depth and group_blocks must be >= 1, got "
                f"({pipeline_depth}, {group_blocks})")
        if cache_blocks < pipeline_depth + group_blocks:
            # the pipelined walk holds one group of G blocks being
            # refined plus D speculative reads landing behind it; below
            # D + G a landing speculation could evict a group member
            # mid-assembly and force a same-batch re-read, breaking the
            # at-most-once billing contract
            raise ValueError(
                f"cache_blocks must cover the pipeline: >= pipeline_depth "
                f"+ group_blocks = {pipeline_depth + group_blocks}, got "
                f"{cache_blocks}")
        self.index = index
        self.pipeline_depth = pipeline_depth
        self.group_blocks = group_blocks
        self.cache = BlockCache(
            index.host_raw, cache_blocks, readers=readers,
            max_inflight=max(2 * readers, pipeline_depth + group_blocks))
        self.batches = 0
        self.cache_hits = 0
        self.blocks_fetched = 0
        self.last_telemetry: dict = {}
        self._closed = False
        # built lazily on first submit()
        self._coalescer = None         # guarded by: _coalescer_lock
        self._coalescer_lock = sanitize.create_lock()

    def _knobs(self, pipeline_depth: int | None,
               group_blocks: int | None) -> tuple[int, int]:
        """Per-call override of the session's pipeline knobs (None =
        session default), validated against the cache capacity."""
        d = self.pipeline_depth if pipeline_depth is None else pipeline_depth
        g = self.group_blocks if group_blocks is None else group_blocks
        if d < 1 or g < 1:
            raise ValueError(f"pipeline_depth and group_blocks must be "
                             f">= 1, got ({d}, {g})")
        if d + g > self.cache.capacity_blocks:
            raise ValueError(
                f"pipeline_depth + group_blocks = {d + g} exceeds the "
                f"session's cache capacity ({self.cache.capacity_blocks} "
                "blocks); enlarge cache_blocks or shrink the pipeline")
        return d, g

    @property
    def hit_rate(self) -> float:
        """Fraction of surviving-block touches served without disk I/O."""
        return self.cache_hits / max(self.cache_hits + self.blocks_fetched, 1)

    def close(self) -> None:
        """Release the cache's reader thread and device blocks (idempotent).

        Submitted-but-undrained tickets are NOT answered — drain first.
        """
        if self._closed:
            return
        self._closed = True
        self.cache.close()

    def __enter__(self) -> "SearchSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _bill(self, tracker: _TouchTracker, *, carry_blocks: int = 0,
              carry_bytes: int = 0, batches: int = 1,
              blocks_refined: int = 0) -> IOStats:
        """Close out one accounting unit: its ``IOStats``, rolled into
        the session totals.  ``carry_*`` are disk reads billed into this
        unit from a resumed round 1; ``batches`` is how many logical
        query batches the unit answered (a coalesced drain bills once
        for N); ``blocks_refined`` is how many distinct blocks the
        unit's walk(s) actually refined — fetched + hit - refined is the
        unit's speculated-but-pruned overshoot."""
        fetched = tracker.disk_blocks + carry_blocks
        io = IOStats(bytes_read=tracker.disk_bytes + carry_bytes,
                     bytes_scan=(self.index.n_real * self.index.n
                                 * self.index.host_raw.dtype.itemsize),
                     blocks_fetched=fetched,
                     blocks_total=self.index.n_blocks,
                     cache_hits=tracker.hits,
                     blocks_refined=blocks_refined)
        self.batches += batches
        self.cache_hits += tracker.hits
        self.blocks_fetched += fetched
        return io

    def _plan(self, k: int, lb_filter: bool, normalize_queries: bool,
              metric) -> engine.QueryPlan:
        if metric is None:
            metric = engine.ED(normalize=normalize_queries,
                               lb_filter=lb_filter)
        return engine.QueryPlan(metric=metric, schedule="block_major", k=k)

    def approximate_threshold(self, queries: jax.Array, *, k: int = 1,
                              lb_filter: bool = True,
                              normalize_queries: bool = True,
                              metric=None,
                              pipeline_depth: int | None = None,
                              group_blocks: int | None = None
                              ) -> PreparedRound:
        """Stage A only -> a resumable ``PreparedRound`` (round 1).

        Round 1 of the distributed out-of-core protocol
        (``distributed.search_sharded_ooc``): each shard refines just
        its queries' best-envelope blocks; ``PreparedRound.threshold``
        (also ``np.asarray(prepared)``) is the (Q,) squared k-th-best
        the protocol min-reduces across shards.  Pass the object to
        ``search(..., prepared=...)`` and round 2 resumes it — no
        re-prep, no re-ranking, no re-fetch or re-refine of stage-A
        blocks — with round 1's disk reads billed into that batch's
        ``IOStats`` and its touch-set continued, so the protocol's full
        I/O cost lands in exactly one bill, comparable to a blind
        single-round search.  Dropping the object abandons the round:
        its reads are billed to no batch.
        """
        plan = self._plan(k, lb_filter, normalize_queries, metric)
        d, g = self._knobs(pipeline_depth, group_blocks)
        tracker = _TouchTracker(self.cache)
        state = engine.run_cached_stage_a(
            self.index, queries, plan,
            fetch=tracker.fetch, speculate=tracker.speculate,
            pipeline_depth=d, group_blocks=g)
        self.cache.drain()
        return PreparedRound(self, plan, _query_signature(queries), state,
                             carry_blocks=tracker.disk_blocks,
                             carry_bytes=tracker.disk_bytes,
                             touched=tracker.touched, hits=tracker.hits)

    def _check_prepared(self, prepared: PreparedRound, plan, qsig) -> None:
        if prepared.session is not self:
            raise ValueError("prepared round belongs to a different "
                             "SearchSession — round 2 must run on the "
                             "session whose approximate_threshold made it")
        if prepared.consumed:
            raise ValueError("prepared round already consumed — each "
                             "PreparedRound resumes exactly one search()")
        if prepared.plan != plan:
            raise ValueError(f"prepared round was built for plan "
                             f"{prepared.plan} but search() asks {plan}; "
                             "k/metric/lb_filter must match round 1")
        if prepared.qsig != qsig:
            raise ValueError("prepared round was built for a different "
                             "query batch — its frontier and block "
                             "ranking do not apply to these queries")

    def search(self, queries: jax.Array, *, k: int = 1,
               lb_filter: bool = True,
               normalize_queries: bool = True,
               metric=None,
               initial_threshold: jax.Array | None = None,
               prepared: PreparedRound | None = None,
               deadline_blocks: int | None = None,
               pipeline_depth: int | None = None,
               group_blocks: int | None = None):
        """Exact k-NN for one (Q, n) query batch through the cache.

        The walk is ``engine.run_cached`` — the §5 block-major schedule
        (envelope ranking, stage-A seeding, suffix-min stopping) with
        every fetch and every speculative prefetch going through the
        id-keyed cache.  ``metric`` picks the plan's metric axis
        (default ``ED``; ``lb_filter``/``normalize_queries`` are folded
        into the default and ignored when an explicit metric is given).
        ``initial_threshold`` (squared) seeds the pruning bound — the
        distributed protocol passes the globally-reduced k-th best; it
        never appears in the result, which holds this shard's own top-k.
        ``prepared`` resumes a round-1 ``PreparedRound`` from this
        session's ``approximate_threshold`` (same queries and plan) or
        an anytime answer's continuation: the walk skips stage A and
        every already-refined block, and this batch's ``IOStats`` bills
        the round's carried reads and continues its touch-set.

        ``deadline_blocks`` caps post-stage-A refines and switches the
        return type to a certified ``serve.AnytimeResult`` (the current
        top-k, a two-sided bound on the true k-th distance, and a
        ``refine_to_exact()`` continuation); ``None`` (default) returns
        the exact ``OocSearchResult``.  A deadline cannot be combined
        with ``initial_threshold`` or ``prepared`` — the anytime
        contract is a fresh batch's.

        ``pipeline_depth``/``group_blocks`` override the session's walk
        pipeline for this batch (None = session default): D speculative
        reads in flight behind the reader pool, G consecutive surviving
        blocks batched per dispatch with ONE threshold sync per group.
        Answers are bit-identical for every setting — the knobs trade
        speculative I/O for latency, never exactness (see
        ``engine.run_cached``).  The walk's host-side counters land in
        ``session.last_telemetry``.
        """
        index = self.index
        plan = self._plan(k, lb_filter, normalize_queries, metric)
        d, g = self._knobs(pipeline_depth, group_blocks)
        if deadline_blocks is not None:
            if deadline_blocks < 1:
                raise ValueError(f"deadline_blocks must be >= 1 (or None "
                                 f"for an exact search), "
                                 f"got {deadline_blocks}")
            if initial_threshold is not None or prepared is not None:
                raise ValueError("deadline_blocks cannot be combined with "
                                 "initial_threshold or prepared — an "
                                 "anytime answer starts a fresh batch")

        # per-run accounting: one touch-set per protocol run (see
        # _TouchTracker), so a block round 1 fetched can never be
        # re-counted as a warm hit by the round 2 that resumes it.
        if prepared is not None:
            self._check_prepared(prepared, plan, _query_signature(queries))
            prepared.consumed = True
            tracker = _TouchTracker(self.cache, prepared.touched,
                                    prepared.hits)
            carry_blocks, carry_bytes = (prepared.carry_blocks,
                                         prepared.carry_bytes)
        else:
            tracker = _TouchTracker(self.cache)
            carry_blocks = carry_bytes = 0

        run_plan = (plan if deadline_blocks is None else
                    dataclasses.replace(plan,
                                        deadline_blocks=deadline_blocks))
        tel: dict = {}
        front, stats, state = engine.run_cached(
            index, queries, run_plan,
            fetch=tracker.fetch, speculate=tracker.speculate,
            initial_threshold=initial_threshold,
            prepared=None if prepared is None else prepared.state,
            pipeline_depth=d, group_blocks=g, telemetry=tel)
        self.last_telemetry = tel

        self.cache.drain()  # settle the last speculation into this bill
        io = self._bill(tracker, carry_blocks=carry_blocks,
                        carry_bytes=carry_bytes,
                        blocks_refined=len(state.refined))
        dist = frontier_lib.result_dists(front)
        if deadline_blocks is None:
            return OocSearchResult(dist=dist, idx=front.ids,
                                   stats=stats, io=io)
        from repro.serve.anytime import AnytimeResult, certify
        resume = PreparedRound(self, plan, _query_signature(queries), state,
                               carry_blocks=0, carry_bytes=0,
                               touched=set(), hits=0)
        return AnytimeResult(dist=dist, idx=front.ids, stats=stats, io=io,
                             certificate=certify(state), resume=resume,
                             queries=jnp.asarray(queries))

    # -- concurrent serving (serve.AdmissionCoalescer) -------------------

    def submit(self, queries: jax.Array, *, k: int = 1,
               lb_filter: bool = True, normalize_queries: bool = True,
               metric=None):
        """Admit a query batch for coalesced serving -> ``serve.Ticket``.

        Thread-safe and non-blocking: concurrent callers each get a
        ticket immediately; the next ``drain()`` (or the first caller
        to block on ``Ticket.result()``) answers every pending ticket
        in ONE coalesced priority walk — each block read from disk at
        most once for all of them.  Results are bit-identical to
        ``search`` on each batch alone.
        """
        return self._get_coalescer().submit(
            queries, self._plan(k, lb_filter, normalize_queries, metric))

    def _get_coalescer(self):
        """The session's coalescer, created on first use.  The whole
        check-create-read runs under the lock: the old double-checked
        fast path read ``_coalescer`` off-lock, which the lock checker
        (LOCK001) rightly rejects — on a weak memory model a second
        thread could observe the reference before the coalescer's own
        fields."""
        with self._coalescer_lock:
            if self._coalescer is None:
                from repro.serve.coalescer import AdmissionCoalescer
                self._coalescer = AdmissionCoalescer(self)
            return self._coalescer

    def drain(self, *, deadline_blocks: int | None = None) -> list:
        """Answer every pending ``submit`` in one coalesced walk.

        Returns the resolved tickets (empty list if nothing pending).
        With ``deadline_blocks``, the shared walk stops after that many
        refines past stage A and unfinished tickets resolve to certified
        ``serve.AnytimeResult``s instead of exact results.
        """
        with self._coalescer_lock:
            co = self._coalescer
        if co is None:
            return []
        return co.drain(deadline_blocks=deadline_blocks)
