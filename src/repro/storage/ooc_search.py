"""Out-of-core exact k-NN over an opened index file (DESIGN.md §5).

The ParIS+ query answering architecture: iSAX summaries and block
envelopes are device-resident, raw series stay on disk.  Per query batch:

  1. one envelope lower-bound kernel pass ranks every block (device);
  2. stage A seeds the shared top-k ``Frontier`` from each query's
     best-envelope block (those blocks are fetched — the only raw I/O a
     fully-pruned query ever costs);
  3. the block-major schedule of ``search.search_block_major`` runs at the
     Python level: blocks in ascending min-over-queries lower-bound order,
     each surviving block fetched memmap -> host -> device and refined by
     the shared ``search.refine_panel``; the suffix-min stopping rule ends
     the walk as soon as no later block can improve any query's top-k.

I/O/compute overlap (the ParIS+ contribution) comes from JAX async
dispatch: the refine step for block i is enqueued and returns immediately,
so the host reads block i+1 off disk and enqueues its DMA while the device
is still computing — a one-block-ahead prefetch.  The loop blocks only on
the (Q,) pruning threshold, once per refined block.

Prefetch is threshold-speculative: block i+1 is chosen with the bound as
of block i-1.  The bound only tightens, so a speculated block is never
refined wrongly — at worst its bytes were read and it is dropped; those
bytes are charged to ``IOStats`` (honesty over optimism).

``IOStats.bytes_read`` vs ``bytes_scan`` is the measurable form of the
paper's pruning claim: an indexed query answers exactly while reading a
small fraction of the raw bytes a scan would.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import numpy as np

from repro.core import frontier as frontier_lib
from repro.core.frontier import SearchStats
from repro.core.index import BlockIndex
from repro.core.search import refine_panel
from repro.kernels import ops


class IOStats(NamedTuple):
    """Raw-byte I/O accounting for one out-of-core query batch."""
    bytes_read: int       # raw bytes actually fetched off disk
    bytes_scan: int       # raw bytes a full scan would read (n_real * n * 4)
    blocks_fetched: int   # distinct block fetches (incl. speculative ones)
    blocks_total: int

    @property
    def read_fraction(self) -> float:
        """bytes_read / bytes_scan — the pruning ratio, in bytes."""
        return self.bytes_read / max(self.bytes_scan, 1)


class OocSearchResult(NamedTuple):
    """Same leading fields as search.SearchResult, plus I/O accounting."""
    dist: jax.Array       # (Q, K) exact k-NN distances, ascending
    idx: jax.Array        # (Q, K) original ids; -1 = fewer than K real
    stats: SearchStats
    io: IOStats

    @property
    def nn_dist(self) -> jax.Array:
        return self.dist[..., 0]

    @property
    def nn_idx(self) -> jax.Array:
        return self.idx[..., 0]


@functools.partial(jax.jit, static_argnames=("n", "w", "lb_filter"))
def _refine_step(q, q_paa, front, stats, block, ids_b, lo, hi, lbs, *,
                 n: int, w: int, lb_filter: bool):
    """One fetched block against all queries — the device side of the loop."""
    thr = frontier_lib.bound(front)
    active = lbs < thr
    return refine_panel(q, q_paa, front, stats, block, ids_b, lo, hi,
                        active, thr, n=n, w=w, lb_filter=lb_filter)


def ooc_search(index: BlockIndex, queries: jax.Array, *, k: int = 1,
               lb_filter: bool = True,
               normalize_queries: bool = True) -> OocSearchResult:
    """Exact k-NN for (Q, n) queries against an index opened out-of-core.

    ``index`` must come from ``storage.open_index`` (or ``build_on_disk``):
    summaries on device, raw behind ``index.host_raw``.  Result dist/idx
    are identical to ``search.search`` / ``ucr.search_scan`` on the same
    data — the streaming changes what is read, never what is answered.
    """
    host = index.host_raw
    if host is None:
        raise ValueError("index has no host_raw — open it with "
                         "storage.open_index (or pass a built index to "
                         "core.search instead)")
    setup = frontier_lib.prepare(queries, k, w=index.w,
                                 normalize=normalize_queries)
    q, q_paa, front = setup.q, setup.q_paa, setup.frontier
    stats = setup.stats
    n, w = index.n, index.w
    n_blocks = index.n_blocks
    refine = functools.partial(_refine_step, n=n, w=w, lb_filter=lb_filter)

    block_lb = ops.lb_scan_planar(q_paa, index.elo, index.ehi, n=n)  # (Q, B)
    block_lb_h = np.asarray(block_lb)

    io = {"bytes": 0, "fetches": 0}

    def stage(b: int):
        """memmap -> host copy -> async DMA; charges the bytes."""
        io["bytes"] += host.block_nbytes
        io["fetches"] += 1
        return jax.device_put(host.fetch(b))

    def step(front, stats, dev_block, b: int):
        ids_b = index.ids[b]
        lo = index.slo[b] if lb_filter else None
        hi = index.shi[b] if lb_filter else None
        return refine(q, q_paa, front, stats, dev_block, ids_b, lo, hi,
                      block_lb[:, b])

    # -- stage A: each query's best-envelope block seeds the frontier ----
    # Each stage-A step refines the block for every query whose envelope
    # bound beats the then-current threshold; the others are validly
    # pruned forever (the bound only tightens) — so these blocks are DONE
    # and drop out of the walk below.
    done = set()
    for b in np.unique(np.argmin(block_lb_h, axis=1)):
        front, stats = step(front, stats, stage(int(b)), int(b))
        done.add(int(b))

    # -- block-major walk over the surviving schedule --------------------
    order = np.argsort(block_lb_h.min(axis=0), kind="stable")     # (B,)
    sched_lb = block_lb_h[:, order]                               # (Q, B)
    suffix = np.minimum.accumulate(sched_lb[:, ::-1], axis=1)[:, ::-1]

    def pending(ptr: int) -> bool:
        """Block at schedule slot ptr still needs a fetch under thr_h."""
        return int(order[ptr]) not in done \
            and bool(np.any(sched_lb[:, ptr] < thr_h))

    thr_h = np.asarray(frontier_lib.bound(front))                 # sync
    prefetched: tuple[int, object] | None = None
    ptr = 0
    while ptr < n_blocks:
        if np.all(suffix[:, ptr] >= thr_h):
            break                           # nothing later helps any query
        if not pending(ptr):
            ptr += 1
            continue                        # pruned (or stage-A-refined)
        dev = prefetched[1] if prefetched and prefetched[0] == ptr \
            else stage(int(order[ptr]))
        prefetched = None
        front, stats = step(front, stats, dev, int(order[ptr]))   # async
        nxt = ptr + 1                       # next survivor under current thr
        while nxt < n_blocks and not pending(nxt):
            nxt += 1
        if nxt < n_blocks and not np.all(suffix[:, nxt] >= thr_h):
            prefetched = (nxt, stage(int(order[nxt])))  # overlaps refine
        thr_h = np.asarray(frontier_lib.bound(front))   # one sync per block
        # blocks in (ptr, nxt) were pruned under a bound that only
        # tightened since — safe to jump straight to the prefetch target
        ptr = nxt

    io_stats = IOStats(bytes_read=io["bytes"],
                       bytes_scan=index.n_real * n * 4,
                       blocks_fetched=io["fetches"],
                       blocks_total=n_blocks)
    return OocSearchResult(dist=frontier_lib.result_dists(front),
                           idx=front.ids, stats=stats, io=io_stats)
