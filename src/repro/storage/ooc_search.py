"""Out-of-core exact k-NN over an opened index file (DESIGN.md §5).

The ParIS+ query answering architecture: iSAX summaries and block
envelopes are device-resident, raw series stay on disk.  Per query batch:

  1. one envelope lower-bound kernel pass ranks every block (device);
  2. stage A seeds the shared top-k ``Frontier`` from each query's
     best-envelope block (those blocks are fetched — the only raw I/O a
     fully-pruned query ever costs);
  3. the block-major schedule of ``search.search_block_major`` runs at the
     Python level: blocks in ascending min-over-queries lower-bound order,
     each surviving block refined by the shared ``engine.panel_refine``;
     the suffix-min stopping rule ends the walk as soon as no later block
     can improve any query's top-k.

The walk itself is ``core.engine.run_cached`` driven by a
``storage.cache.SearchSession``: all raw I/O — fetches and the
one-block-ahead threshold-speculative prefetch alike — goes through a
``BlockCache`` (an id-keyed LRU of device-resident blocks with a
background reader thread), so disk reads overlap device compute
without the driver thread ever blocking in a copy, and a speculated
block whose schedule slot gets pruned simply stays cached under its id.
The walk is metric-generic: ``metric=engine.DTW(r)`` is out-of-core
DTW, ``metric=engine.Cosine()`` serves embeddings.
``ooc_search`` below is the stateless one-shot form: a throwaway session
with a small cache, keeping a single batch's device footprint at a few
blocks.  Serving workloads should hold a ``SearchSession`` instead and
let repeated traffic hit the cache.

``IOStats.bytes_read`` vs ``bytes_scan`` is the measurable form of the
paper's pruning claim: an indexed query answers exactly while reading a
small fraction of the raw bytes a scan would.  ``cache_hits`` keeps that
claim measurable under caching, by separating blocks that survived
pruning but cost no disk traffic.  In the two-round distributed
protocol (``distributed.search_sharded_ooc``), one protocol run is one
billing unit: round 1 returns a ``storage.PreparedRound`` whose reads
and touch-set the consuming round-2 ``search`` bills, so the stage-A
blocks appear once as reads — never again as round-2 warm hits — and
an abandoned round 1 is billed to no batch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from repro.core.frontier import SearchStats
from repro.core.index import BlockIndex


class IOStats(NamedTuple):
    """Raw-byte I/O accounting for one out-of-core query batch."""
    bytes_read: int       # raw bytes actually fetched off disk
    bytes_scan: int       # raw bytes a full scan would read
                          #   (n_real * n * raw itemsize)
    blocks_fetched: int   # disk block reads (each block at most once/batch)
    blocks_total: int
    cache_hits: int = 0   # surviving blocks served from the device cache
    blocks_refined: int = 0  # distinct blocks the walk actually refined;
                             # fetched + hits - refined = speculative
                             # reads the threshold pruned before use

    @property
    def read_fraction(self) -> float:
        """bytes_read / bytes_scan — the pruning ratio, in bytes."""
        return self.bytes_read / max(self.bytes_scan, 1)


class OocSearchResult(NamedTuple):
    """Same leading fields as search.SearchResult, plus I/O accounting."""
    dist: jax.Array       # (Q, K) exact k-NN distances, ascending
    idx: jax.Array        # (Q, K) original ids; -1 = fewer than K real
    stats: SearchStats
    io: IOStats

    @property
    def nn_dist(self) -> jax.Array:
        return self.dist[..., 0]

    @property
    def nn_idx(self) -> jax.Array:
        return self.idx[..., 0]


def ooc_search(index: BlockIndex, queries: jax.Array, *, k: int = 1,
               lb_filter: bool = True, normalize_queries: bool = True,
               cache_blocks: int = 4, metric=None,
               pipeline_depth: int = 1, group_blocks: int = 1,
               readers: int = 2) -> OocSearchResult:
    """Exact k-NN for (Q, n) queries against an index opened out-of-core.

    ``index`` must come from ``storage.open_index`` (or ``build_on_disk``):
    summaries on device, raw behind ``index.host_raw``.  Result dist/idx
    are identical to ``search.search`` / ``ucr.search_scan`` on the same
    data — the streaming changes what is read, never what is answered.
    ``metric`` picks the plan's metric axis (``engine.DTW(r)`` is
    out-of-core DTW, ``engine.Cosine()`` serves embeddings; default ED).

    ``pipeline_depth``/``group_blocks``/``readers`` tune the walk
    pipeline (speculative reads in flight / blocks per batched refine /
    cache reader threads); every setting answers bit-identically, see
    ``engine.run_cached``.  ``cache_blocks`` is raised automatically to
    the ``pipeline_depth + group_blocks`` floor the session requires.

    One-shot wrapper over ``cache.SearchSession``: the session (and its
    ``cache_blocks``-bounded device cache) lives only for this call, so
    every batch pays cold-disk cost.  Hold a ``SearchSession`` yourself
    to serve repeated traffic warm.
    """
    from repro.storage.cache import SearchSession
    with SearchSession(index,
                       cache_blocks=max(cache_blocks,
                                        pipeline_depth + group_blocks),
                       readers=readers, pipeline_depth=pipeline_depth,
                       group_blocks=group_blocks) as session:
        return session.search(queries, k=k, lb_filter=lb_filter,
                              normalize_queries=normalize_queries,
                              metric=metric)
