from repro.data.generators import random_walk, sald_like, seismic_like, make_dataset
from repro.data.loader import ChunkedLoader, IncrementalBuilder
from repro.data.tokens import synthetic_token_batches

__all__ = [
    "random_walk", "sald_like", "seismic_like", "make_dataset",
    "ChunkedLoader", "IncrementalBuilder", "synthetic_token_batches",
]
