"""Synthetic token pipeline for the LM wing (training drivers + smoke tests).

Deterministic, dependency-free stand-in for a real tokenized corpus: a Zipf
-distributed token stream with short-range structure (each document cycles
through a per-document offset so next-token prediction is learnable — loss
visibly decreases in examples/train_lm.py, which is how we verify the
training loop does real work).  Yields {tokens, labels} with labels = tokens
shifted left, -100 marking padding (ignored by the loss).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

IGNORE = -100


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return (p / p.sum()).astype(np.float64)


def synthetic_token_batches(*, batch: int, seq_len: int, vocab: int,
                            seed: int = 0, structured: bool = True,
                            ) -> Iterator[dict[str, np.ndarray]]:
    """Infinite iterator of {tokens (B, L) int32, labels (B, L) int32}."""
    rng = np.random.default_rng(seed)
    base_vocab = min(vocab, 4096)          # sample in a small head for speed
    probs = _zipf_probs(base_vocab)
    while True:
        toks = rng.choice(base_vocab, size=(batch, seq_len), p=probs)
        if structured:
            # learnable pattern: with p=0.5 the next token repeats the
            # current one shifted by a per-sequence constant (mod head)
            shift = rng.integers(1, 17, size=(batch, 1))
            repeat = rng.random((batch, seq_len)) < 0.5
            shifted = (toks + shift) % base_vocab
            toks[:, 1:] = np.where(repeat[:, 1:], shifted[:, :-1], toks[:, 1:])
        toks = toks.astype(np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((batch, 1), IGNORE, np.int32)], axis=1)
        yield {"tokens": toks, "labels": labels}


def token_stream_for_arch(cfg, *, batch: int, seq_len: int, seed: int = 0):
    """Batches sized for a model config (clamps vocab into the config's)."""
    return synthetic_token_batches(batch=batch, seq_len=seq_len,
                                   vocab=cfg.vocab, seed=seed)
