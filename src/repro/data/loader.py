"""Chunked, double-buffered ingestion — the ParIS+ I/O/compute overlap.

Paper mapping (DESIGN.md §2): the Coordinator thread streams raw series from
disk into the raw-data buffer while IndexBulkLoading workers summarize the
previous batch; ParIS+'s contribution is that the summarization+tree work
completely hides behind the I/O.  On a TPU system the expensive ingress link
is host RAM -> HBM, and the overlap mechanism is JAX's asynchronous dispatch:
``jax.device_put`` of chunk k+1 and the summarize/build computation on chunk
k are both enqueued without blocking, so the DMA of the next chunk runs under
the compute of the current one.  ``ChunkedLoader`` owns that staging;
``IncrementalBuilder`` is the bulk-loading worker pool (one summarize kernel
launch per chunk), with the final sort/partition as the construction stage.
"""
from __future__ import annotations

import os
from typing import Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isax
from repro.core import index as index_lib
from repro.core.index import BlockIndex
from repro.kernels import ops


class ChunkedLoader:
    """Iterate a host dataset in fixed-size chunks with one-chunk prefetch.

    ``source`` is a host ndarray (sliced lazily — the "file"), a callable
    ``(start, stop) -> np.ndarray`` (a reader), or a ``str | Path`` to a
    headerless row-major series file, which is np.memmap'd and needs
    ``length`` (points per series; see storage.format.SeriesStore).  The
    loader keeps at most two chunks in flight: the one the consumer holds
    and the one being staged to device — the paper's double buffer.
    """

    def __init__(self, source, n_series: int | None = None, *,
                 chunk: int = 1 << 16, device=None,
                 length: int | None = None, dtype=np.float32):
        if isinstance(source, (str, os.PathLike)):
            if length is None:
                raise ValueError("length required for a file source")
            mm = np.memmap(source, dtype=np.dtype(dtype), mode="r")
            if mm.size % length:
                raise ValueError(f"{source}: size {mm.size} not a multiple "
                                 f"of series length {length}")
            mm = mm.reshape(-1, length)
            self._read = lambda a, b: mm[a:b]
            self.n_series = mm.shape[0] if n_series is None else n_series
        elif callable(source):
            if n_series is None:
                raise ValueError("n_series required for a callable source")
            self._read = source
            self.n_series = n_series
        else:
            self._read = lambda a, b: source[a:b]
            self.n_series = len(source) if n_series is None else n_series
        self.chunk = chunk
        self.device = device or jax.devices()[0]

    def __len__(self) -> int:
        return (self.n_series + self.chunk - 1) // self.chunk

    def __iter__(self) -> Iterator[jax.Array]:
        nxt = self._stage(0)
        for start in range(self.chunk, self.n_series, self.chunk):
            cur, nxt = nxt, self._stage(start)   # enqueue DMA of k+1 ...
            yield cur                            # ... before k is consumed
        yield nxt

    def _stage(self, start: int) -> jax.Array:
        stop = min(start + self.chunk, self.n_series)
        host = np.asarray(self._read(start, stop), dtype=np.float32)
        return jax.device_put(host, self.device)  # async: returns immediately


def summarize_chunk(chunk: jax.Array, *, w: int, card: int,
                    normalize: bool) -> tuple[jax.Array, jax.Array]:
    """One IndexBulkLoading step: (m, n) raw chunk -> (z-normed, sax).

    The single definition of the per-chunk summarize launch, shared by
    ``IncrementalBuilder`` (keeps both) and the pipeline's pass-1 run
    builder (storage/pipeline/runs.py, keeps only the sax words).  Every
    op is per-row independent, so chunking/sharding the input cannot
    change any series' summary — the invariance the resumable build's
    byte-identity rests on.
    """
    xn = isax.znorm(chunk) if normalize else chunk.astype(jnp.float32)
    _, sax = ops.summarize(xn, w=w, card=card, normalize=False)
    return xn, sax


class IncrementalBuilder:
    """ParIS+-style incremental index construction over a chunk stream.

    Per chunk (the IndexBulkLoading stage): z-normalize + summarize (one
    Pallas ``isax_summarize`` launch) — dispatched asynchronously, so it
    overlaps the staging of the next chunk.  ``finalize()`` (the
    IndexConstruction stage) concatenates, sorts by the interleaved iSAX
    word and cuts fixed-capacity blocks; since the sort sees the global
    order, the result is IDENTICAL to a one-shot ``index.build`` on the full
    array (tested), which is what makes rebuild-from-manifest deterministic.
    """

    def __init__(self, *, w: int = isax.W, card: int = isax.CARD,
                 capacity: int = 512, normalize: bool = True):
        self.w, self.card, self.capacity = w, card, capacity
        self.normalize = normalize
        self._raw: list[jax.Array] = []
        self._sax: list[jax.Array] = []
        self._count = 0

    def add_chunk(self, chunk: jax.Array) -> None:
        xn, sax = summarize_chunk(chunk, w=self.w, card=self.card,
                                  normalize=self.normalize)
        self._raw.append(xn)
        self._sax.append(sax)
        self._count += chunk.shape[0]

    def finalize(self) -> BlockIndex:
        if not self._raw:
            raise ValueError("no chunks added")
        raw = jnp.concatenate(self._raw, axis=0)
        sax = jnp.concatenate(self._sax, axis=0)
        return self._assemble(raw, sax)

    def _assemble(self, raw: jax.Array, sax: jax.Array) -> BlockIndex:
        # identical tail to index.build, but reuses the precomputed summaries
        n_series, n = raw.shape
        ids = jnp.arange(n_series, dtype=jnp.int32)
        bounds = isax.bounds_from_sax(sax, self.card)
        order = isax.sort_order(sax, self.w)
        return index_lib.assemble_blocks(
            raw[order], bounds[order], ids[order], n=n, w=self.w,
            card=self.card, capacity=self.capacity)


def build_streaming(source, *, chunk: int = 1 << 16, capacity: int = 512,
                    w: int = isax.W, card: int = isax.CARD,
                    normalize: bool = True,
                    n_series: int | None = None) -> BlockIndex:
    """End-to-end ParIS+ pipeline: overlapped ingest -> summarize -> build."""
    loader = ChunkedLoader(source, n_series, chunk=chunk)
    builder = IncrementalBuilder(w=w, card=card, capacity=capacity,
                                 normalize=normalize)
    for dev_chunk in loader:
        builder.add_chunk(dev_chunk)
    return builder.finalize()
