"""Dataset generators mirroring the paper's evaluation data.

The paper uses:
  * Synthetic — random walk, 100M series x 256 points (the standard data
    series benchmark generator: x_{t+1} = x_t + N(0,1));
  * SALD      — electroencephalography, 200M x 128;
  * Seismic   — seismic activity records, 100M x 256.

The two real datasets are not redistributable; we generate *surrogates with
matching signal character* (EEG: band-limited oscillatory mixture; seismic:
sparse bursts over low noise) so the pruning-behaviour contrast the paper
reports (random data prunes better than real data, §IV) is reproducible.
Scales are configurable — benchmarks default to laptop-sized slices and the
dry-run/roofline path covers the full-scale shapes.
"""
from __future__ import annotations

import numpy as np


def random_walk(n_series: int, length: int = 256, *, seed: int = 0,
                chunk: int = 1 << 16) -> np.ndarray:
    """The paper's Synthetic generator: cumulative sum of N(0,1) steps."""
    rng = np.random.default_rng(seed)
    out = np.empty((n_series, length), np.float32)
    for i in range(0, n_series, chunk):
        j = min(i + chunk, n_series)
        steps = rng.standard_normal((j - i, length), dtype=np.float32)
        np.cumsum(steps, axis=1, out=out[i:j])
    return out


def sald_like(n_series: int, length: int = 128, *, seed: int = 1) -> np.ndarray:
    """EEG-like surrogate: mixture of alpha/beta/theta band oscillations +
    1/f noise. Matches SALD's 128-point series length."""
    rng = np.random.default_rng(seed)
    t = np.arange(length, dtype=np.float32)
    out = np.zeros((n_series, length), np.float32)
    for band_hz, amp in ((0.04, 1.0), (0.09, 0.7), (0.17, 0.4)):
        f = band_hz * (1.0 + 0.3 * rng.standard_normal((n_series, 1)))
        ph = rng.uniform(0, 2 * np.pi, (n_series, 1))
        a = amp * (0.5 + rng.random((n_series, 1)))
        out += (a * np.sin(2 * np.pi * f * t[None, :] + ph)).astype(np.float32)
    # pink-ish noise via cumulative sum of white noise, lightly mixed
    out += 0.35 * np.cumsum(
        rng.standard_normal((n_series, length), dtype=np.float32), axis=1) \
        / np.sqrt(length)
    return out


def seismic_like(n_series: int, length: int = 256, *, seed: int = 2) -> np.ndarray:
    """Seismic-like surrogate: quiet background + occasional decaying bursts."""
    rng = np.random.default_rng(seed)
    t = np.arange(length, dtype=np.float32)
    noise = 0.1 * rng.standard_normal((n_series, length)).astype(np.float32)
    onset = rng.integers(0, length, (n_series, 1))
    decay = np.exp(-np.maximum(t[None, :] - onset, 0) / (length / 8)) \
        * (t[None, :] >= onset)
    carrier = np.sin(2 * np.pi * 0.12 * t)[None, :] \
        + 0.5 * np.sin(2 * np.pi * 0.31 * t + 1.3)[None, :]
    amp = rng.gamma(2.0, 1.0, (n_series, 1)).astype(np.float32)
    return (noise + amp * decay * carrier).astype(np.float32)


_GENERATORS = {
    "synthetic": random_walk,
    "sald": sald_like,
    "seismic": seismic_like,
}

# The paper's full-scale dataset shapes (for dry-run / roofline accounting).
PAPER_SCALES = {
    "synthetic": (100_000_000, 256),
    "sald": (200_000_000, 128),
    "seismic": (100_000_000, 256),
}


def make_dataset(name: str, n_series: int, length: int | None = None,
                 seed: int | None = None) -> np.ndarray:
    gen = _GENERATORS[name]
    kw = {}
    if length is not None:
        kw["length"] = length
    if seed is not None:
        kw["seed"] = seed
    return gen(n_series, **kw)
