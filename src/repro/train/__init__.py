from repro.train.optimizer import (adamw_init, adamw_update, adafactor_init,
                                   adafactor_update, opt_init, opt_update,
                                   opt_state_specs)
from repro.train.step import make_train_step, make_eval_step
from repro.train.checkpoint import Checkpointer

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "opt_init", "opt_update", "opt_state_specs", "make_train_step",
           "make_eval_step", "Checkpointer"]
