"""Train / eval steps: microbatched grad accumulation, f32 accumulators,
NaN-step skipping (fault tolerance — a bad batch never corrupts the params),
and an LR schedule computed inside the step (no host round-trip).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.train import optimizer as opt_lib


def lr_schedule(step, *, base_lr: float = 3e-4, warmup: int = 100,
                total: int = 10_000, min_frac: float = 0.1):
    """Linear warmup + cosine decay, all in jnp (usable inside jit)."""
    t = step.astype(jnp.float32) + 1.0      # first update gets lr > 0
    warm = t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(t < warmup, warm, cos)


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def _all_finite(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    fin = jnp.ones((), jnp.bool_)
    for l in leaves:
        fin = jnp.logical_and(fin, jnp.all(jnp.isfinite(l)))
    return fin


def make_train_step(cfg, *, mesh=None, data_axes: tuple[str, ...] = (),
                    base_lr: float = 3e-4, total_steps: int = 10_000,
                    warmup: int = 100, triangular: bool = False,
                    microbatch: int | None = None) -> Callable:
    """Build the jit-able train step for one architecture config.

    Signature: (params, opt_state, batch) -> (params, opt_state, metrics).
    Gradients are accumulated in f32 across ``cfg.microbatch`` microbatches
    (a ``lax.scan``, so HLO size is constant in the count); non-finite
    grads skip the update and bump ``metrics["skipped"]``.
    """
    mb = microbatch if microbatch is not None else max(1, cfg.microbatch)
    kind = cfg.optimizer

    def loss_for(params, batch):
        return transformer.loss_fn(params, batch, cfg, mesh=mesh,
                                   data_axes=data_axes,
                                   triangular=triangular)

    def train_step(params, opt_state, batch):
        if mb > 1:
            split = jax.tree.map(
                lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]),
                batch)

            def acc(carry, mb_batch):
                g_acc, l_acc = carry
                (l, met), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, mb_batch)
                g = jax.tree.map(lambda x, y: x + y.astype(jnp.float32),
                                 g_acc, g)
                return (g, l_acc + l), met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), mets = jax.lax.scan(
                acc, (g0, jnp.zeros(())), split)
            grads = _tree_scale(grads, 1.0 / mb)
            loss = loss / mb
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), mets)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)

        lr = lr_schedule(opt_state.step, base_lr=base_lr, warmup=warmup,
                         total=total_steps)
        new_params, new_state = opt_lib.opt_update(
            kind, grads, opt_state, params, lr=lr)

        # fault tolerance: skip non-finite updates wholesale
        ok = jnp.logical_and(_all_finite(grads), jnp.isfinite(loss))
        pick = lambda n, o: jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), n, o)
        new_params = pick(new_params, params)
        new_state = jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), new_state,
            opt_state._replace(step=opt_state.step + 1))

        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics)
        metrics.update(loss=loss, lr=lr, grad_norm=gnorm,
                       skipped=(~ok).astype(jnp.int32))
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg, *, mesh=None, data_axes: tuple[str, ...] = ()
                   ) -> Callable:
    def eval_step(params, batch):
        loss, metrics = transformer.loss_fn(params, batch, cfg, mesh=mesh,
                                            data_axes=data_axes)
        return metrics
    return eval_step


def make_serve_step(cfg, *, mesh=None, data_axes: tuple[str, ...] = (),
                    kv_shard: tuple | None = None) -> Callable:
    """One-token decode step (the thing the decode_* shape cells lower)."""
    def serve_step(params, tokens, pos, cache):
        return transformer.decode_step(params, tokens, pos, cache, cfg,
                                       mesh=mesh, data_axes=data_axes,
                                       kv_shard=kv_shard)
    return serve_step


def make_prefill_step(cfg, *, mesh=None, data_axes: tuple[str, ...] = ()
                      ) -> Callable:
    def prefill_step(params, batch, cache):
        return transformer.prefill(params, batch, cache, cfg, mesh=mesh,
                                   data_axes=data_axes)
    return prefill_step
