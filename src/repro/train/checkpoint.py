"""Checkpointing: per-leaf .npy files + JSON manifest, atomic, async, keep-k.

Layout:
    <dir>/step_<n>/
        manifest.json      {"step": n, "leaves": [{"path", "shape", "dtype"}]}
        leaf_00000.npy ...

Properties needed for the fault-tolerance story (DESIGN.md §6):
  * atomic publish — written into ``.tmp-step_<n>`` then os.rename'd, so a
    killed writer never leaves a half checkpoint that restore would trust;
  * async — ``save`` snapshots to host (device_get) in the caller, the file
    writes happen on a worker thread; ``wait()`` drains before exit;
  * keep-last-k — old step dirs pruned after successful publish;
  * elastic restore — leaves are whole (unsharded) arrays; ``restore`` takes
    a template pytree (structure + shapes) and optional shardings, so the
    same checkpoint restores onto any mesh shape / device count (tested
    8 -> 4 in tests/test_distributed.py).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_writes: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue | None = None
        self._worker: threading.Thread | None = None
        self._err: list[BaseException] = []
        if async_writes:
            self._q = queue.Queue(maxsize=2)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- public ---------------------------------------------------------

    def save(self, step: int, tree: Any) -> None:
        """Snapshot ``tree`` (host copy taken now) and persist it."""
        if self._err:
            raise self._err.pop()
        leaves, _ = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        if self._q is not None:
            self._q.put((step, host))
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._q is not None:
            self._q.join()
        if self._err:
            raise self._err.pop()

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.startswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, template: Any, *, step: int | None = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint into the structure of ``template``.

        ``shardings``: optional pytree of jax.sharding.Sharding matching the
        template — arrays are placed with it (elastic reshard on restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        t_leaves, treedef = _flatten(template)
        if len(manifest["leaves"]) != len(t_leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, template "
                f"has {len(t_leaves)} — structure mismatch")
        s_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(t_leaves))
        out = []
        for meta, tmpl, shard in zip(manifest["leaves"], t_leaves, s_leaves):
            arr = np.load(os.path.join(d, meta["path"]))
            tshape = getattr(tmpl, "shape", None)
            if tshape is None:                  # python scalar leaf
                out.append(arr.item() if arr.ndim == 0 else arr)
                continue
            if tuple(arr.shape) != tuple(tshape):
                raise ValueError(
                    f"leaf {meta['path']}: shape {arr.shape} != template "
                    f"{tshape}")
            out.append(jax.device_put(arr, shard) if shard is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- internals --------------------------------------------------------

    def _drain(self):
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            except BaseException as e:       # surfaced on next save()/wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host: list[np.ndarray]) -> None:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = os.path.join(self.dir, f".tmp-step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        metas = []
        for i, arr in enumerate(host):
            path = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, path), arr)
            metas.append({"path": path, "shape": list(arr.shape),
                          "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": metas}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                # atomic publish
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
