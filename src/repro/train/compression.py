"""Gradient compression: int8 quantization with error feedback.

In the implicit-DP (pjit) path XLA owns the gradient all-reduce, so
compression is exposed as an *explicit-DP* alternative: per-shard grads are
quantized to int8 (per-leaf absmax scale), exchanged with an ``all_gather``
over the data axes (int8 on the wire — 4x fewer bytes than f32), and
dequant-summed locally.  The quantization residual feeds back into the next
step's gradient (error feedback), which is what keeps convergence intact —
``tests/test_train.py`` checks a quadratic converges with compression on.

Honesty note (DESIGN.md §6): a production int8 *all-reduce* needs
reduction-over-int8 support in the collective itself; XLA reduces in the
operand dtype, and int8 sums overflow.  all_gather+local-sum keeps int8 on
the wire at the cost of O(N) receive buffers — the right trade for the
gradient sizes here; both variants' collective bytes are visible in the
dry-run HLO.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def quantize8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-leaf absmax int8 quantization. Returns (q, scale)."""
    s = jnp.max(jnp.abs(g)) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
    return q, s


def dequant8(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + err); the new residual is returned as next-step err."""
    gc = g.astype(jnp.float32) + err
    q, s = quantize8(gc)
    return q, s, gc - dequant8(q, s)


def compress_allreduce(g: jax.Array, e: jax.Array, ax, n: int
                       ) -> tuple[jax.Array, jax.Array]:
    """int8 mean-reduce of one per-shard gradient leaf.

    For use INSIDE a shard_map whose data axes are ``ax`` (each shard holds
    its own local gradient).  Returns (mean grad, new error state)."""
    q, s, new_e = compress_with_feedback(g, e)
    qs = jax.lax.all_gather(q, ax)                       # int8 on the wire
    ss = jax.lax.all_gather(s, ax)
    tot = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
    return tot / n, new_e


def ddp_allreduce_int8(grads: Any, err: Any, mesh: Mesh,
                       data_axes: tuple[str, ...]) -> tuple[Any, Any]:
    """Explicit-DP mean of per-shard grads with int8 wire format.

    ``grads``/``err``: pytrees whose leaves carry a leading per-shard dim
    (n_shards, *shape), sharded over the data axes.  Returns (mean gradient,
    replicated; new per-shard error state, same layout as input).
    """
    ax = data_axes if len(data_axes) > 1 else data_axes[0]
    n = 1
    for a in data_axes:
        n *= mesh.shape[a]

    def body(g, e):
        return compress_allreduce(g[0], e[0], ax, n)

    def all_leaves(gs, es):
        out = jax.tree.map(body, gs, es)
        leaf = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda o: o[0], out, is_leaf=leaf),
                jax.tree.map(lambda o: o[1][None], out, is_leaf=leaf))

    fn = shard_map(all_leaves, mesh=mesh,
                       in_specs=(P(ax), P(ax)), out_specs=(P(), P(ax)),
                       check_vma=False)
    return fn(grads, err)


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
