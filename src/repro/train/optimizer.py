"""Optimizers from scratch: AdamW and Adafactor.

AdamW keeps two f32 moments per parameter (3x param memory in f32) — fine up
to ~30B at 256 chips with FSDP.  Adafactor factors the second moment of any
rank>=2 leaf into row/col accumulators (O(sum dims) instead of O(prod dims))
and keeps no first moment — the nemotron-4-340b config uses it (see
DESIGN.md §6 memory budget).

States are plain pytrees mirroring the param tree (inapplicable slots hold
size-0 arrays so tree structures always match), so the launch layer derives
their PartitionSpecs from the param specs (``opt_state_specs``) and the
checkpointer treats them like any other tree.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any       # row accumulator (shape[:-1]) for rank>=2 leaves
    vc: Any       # col accumulator (shape[:-2] + shape[-1:])
    v: Any        # full accumulator for rank<2 leaves (size-0 otherwise)


def _empty() -> jax.Array:
    return jnp.zeros((0,), jnp.float32)


# -- AdamW -------------------------------------------------------------------


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.01):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if p.ndim >= 2:                 # decoupled wd on matrices only
            u = u + wd * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaf = lambda x: isinstance(x, tuple)
    pick = lambda i: jax.tree.map(lambda o: o[i], out, is_leaf=leaf)
    return pick(0), AdamWState(step=step, m=pick(1), v=pick(2))


# -- Adafactor ---------------------------------------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    vr = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
        else _empty(), params)
    vc = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        if _factored(p) else _empty(), params)
    v = jax.tree.map(
        lambda p: _empty() if _factored(p) else jnp.zeros(p.shape,
                                                          jnp.float32),
        params)
    return AdafactorState(step=jnp.zeros((), jnp.int32), vr=vr, vc=vc, v=v)


def adafactor_update(grads, state: AdafactorState, params, *, lr,
                     decay: float = 0.8, eps: float = 1e-30,
                     clip: float = 1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - jnp.power(t, -decay)

    def upd(p, g, vr, vc, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            rf = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g * jax.lax.rsqrt(jnp.maximum(rf[..., None], eps)) \
                * jax.lax.rsqrt(jnp.maximum(vc, eps))[..., None, :]
        else:
            v = beta * v + (1 - beta) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)         # update clipping
        u = u / jnp.maximum(1.0, rms / clip)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc, v)

    out = jax.tree.map(upd, params, grads, state.vr, state.vc, state.v)
    leaf = lambda x: isinstance(x, tuple)
    pick = lambda i: jax.tree.map(lambda o: o[i], out, is_leaf=leaf)
    return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2),
                                   v=pick(3))


# -- unified front door ------------------------------------------------------


def opt_init(kind: str, params):
    if kind == "adamw":
        return adamw_init(params)
    if kind == "adafactor":
        return adafactor_init(params)
    raise ValueError(kind)


def opt_update(kind: str, grads, state, params, *, lr, **kw):
    if kind == "adamw":
        return adamw_update(grads, state, params, lr=lr, **kw)
    if kind == "adafactor":
        return adafactor_update(grads, state, params, lr=lr, **kw)
    raise ValueError(kind)


def opt_state_specs(kind: str, param_pspecs, param_shapes):
    """PartitionSpec tree for the optimizer state, mirroring the params.

    Adafactor's factored accumulators drop the last (vr) / second-to-last
    (vc) dim, so their specs drop the matching entry; size-0 sentinels are
    replicated.
    """
    from jax.sharding import PartitionSpec as P
    scalar = P()
    if kind == "adamw":
        return AdamWState(step=scalar, m=param_pspecs, v=param_pspecs)

    def drop(spec, shape, which):
        if len(shape) < 2:
            return P()
        ent = list(spec) + [None] * (len(shape) - len(spec))
        del ent[-1 if which == "vr" else -2]
        return P(*ent)

    vr = jax.tree.map(lambda s, sh: drop(s, sh.shape, "vr"),
                      param_pspecs, param_shapes)
    vc = jax.tree.map(lambda s, sh: drop(s, sh.shape, "vc"),
                      param_pspecs, param_shapes)
    v = jax.tree.map(lambda s, sh: P() if len(sh.shape) >= 2 else s,
                     param_pspecs, param_shapes)
    return AdafactorState(step=scalar, vr=vr, vc=vc, v=v)


def opt_state_shapes(kind: str, param_shapes):
    """ShapeDtypeStruct tree of the optimizer state (dry-run path)."""
    f32 = jnp.float32
    sds = lambda sh: jax.ShapeDtypeStruct(sh, f32)
    if kind == "adamw":
        return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          m=jax.tree.map(lambda s: sds(s.shape),
                                         param_shapes),
                          v=jax.tree.map(lambda s: sds(s.shape),
                                         param_shapes))
    vr = jax.tree.map(lambda s: sds(s.shape[:-1]) if len(s.shape) >= 2
                      else sds((0,)), param_shapes)
    vc = jax.tree.map(lambda s: sds(s.shape[:-2] + s.shape[-1:])
                      if len(s.shape) >= 2 else sds((0,)), param_shapes)
    v = jax.tree.map(lambda s: sds((0,)) if len(s.shape) >= 2
                     else sds(s.shape), param_shapes)
    return AdafactorState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          vr=vr, vc=vc, v=v)
