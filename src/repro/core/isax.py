"""iSAX representation: PAA, symbols, region bounds, and lower-bound distances.

Faithful to Shieh & Keogh's iSAX as used by ParIS/ParIS+/MESSI:
  * series are z-normalized,
  * PAA with ``w`` equal-length segments (paper fixes w=16),
  * symbols drawn from equiprobable N(0,1) regions (cardinality 256 = 8 bits),
  * MINDIST lower bound:  LB(q, S)^2 = (n/w) * sum_seg max(0, lo-q, q-hi)^2,
    which never exceeds the true Euclidean distance (no false dismissals).

TPU adaptation (see DESIGN.md §2): alongside the packed symbols we keep the
*decompressed region envelope* ``bounds[..., 2]`` (the breakpoint interval of
each symbol) so the lower-bound kernels are pure VPU arithmetic with no
gathers.  Region sentinels are large-but-finite so f32 arithmetic stays
inf/nan-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from scipy.stats import norm

# Paper-fixed defaults.
W = 16          # number of PAA segments ("w is fixed to 16 in this paper")
CARD = 256      # per-segment cardinality (8 bits), as in the ParIS/MESSI SAX array
SENTINEL = 1.0e9  # finite stand-in for +/- infinity region edges


@functools.lru_cache(maxsize=None)
def breakpoints(card: int = CARD) -> np.ndarray:
    """The card-1 equiprobable N(0,1) breakpoints, ascending. float32."""
    qs = np.arange(1, card) / card
    return norm.ppf(qs).astype(np.float32)


@functools.lru_cache(maxsize=None)
def region_tables(card: int = CARD) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) value tables indexed by symbol; edges use finite sentinels."""
    bps = breakpoints(card)
    lo = np.concatenate([[-SENTINEL], bps]).astype(np.float32)   # lo[s] = bps[s-1]
    hi = np.concatenate([bps, [SENTINEL]]).astype(np.float32)    # hi[s] = bps[s]
    return lo, hi


def znorm(x: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Z-normalize each series along the last axis (standard in this literature)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.maximum(sd, eps)


def paa(x: jax.Array, w: int = W) -> jax.Array:
    """Piecewise Aggregate Approximation: mean over n/w windows. (..., n) -> (..., w)."""
    n = x.shape[-1]
    if n % w:
        raise ValueError(f"series length {n} not divisible by w={w}")
    return jnp.mean(x.reshape(*x.shape[:-1], w, n // w), axis=-1)


def sax_from_paa(paa_vals: jax.Array, card: int = CARD) -> jax.Array:
    """Quantize PAA values into symbols [0, card) by counting breakpoints below.

    Equivalent to searchsorted into the ascending breakpoint list; implemented
    as a broadcast-compare + sum, which is the VPU-friendly form the Pallas
    kernel mirrors.
    """
    bps = jnp.asarray(breakpoints(card))
    return jnp.sum(paa_vals[..., None] >= bps, axis=-1).astype(jnp.int32)


def bounds_from_sax(sax, card: int = CARD, *, xp=jnp):
    """Decompress symbols into their region [lo, hi]. (..., w) -> (..., w, 2).

    ``xp`` is the array namespace: jnp (default) for the device builders,
    np for the host side of the out-of-core build pipeline
    (storage/pipeline/) — one definition of the symbol→region decode for
    both, same table lookup, bit-identical f32 values.
    """
    lo_t, hi_t = region_tables(card)
    lo = xp.asarray(lo_t)[sax]
    hi = xp.asarray(hi_t)[sax]
    return xp.stack([lo, hi], axis=-1)


def summarize(x: jax.Array, w: int = W, card: int = CARD,
              normalize: bool = True) -> tuple[jax.Array, jax.Array, jax.Array]:
    """znorm -> (paa, sax, bounds) for a batch of series (..., n)."""
    if normalize:
        x = znorm(x)
    p = paa(x, w)
    s = sax_from_paa(p, card)
    return p, s, bounds_from_sax(s, card)


def mindist_paa_bounds_sq(q_paa: jax.Array, bounds: jax.Array, n: int) -> jax.Array:
    """Squared MINDIST between query PAA (..., w) and region bounds (..., w, 2).

    Broadcasts over leading dims. Returns squared lower bound of the Euclidean
    distance between the query and ANY series whose PAA lies in the bounds.
    """
    lo = bounds[..., 0]
    hi = bounds[..., 1]
    d = jnp.maximum(jnp.maximum(lo - q_paa, q_paa - hi), 0.0)
    w = q_paa.shape[-1]
    return (n / w) * jnp.sum(d * d, axis=-1)


def paa_lb_sq(q_paa: jax.Array, s_paa: jax.Array, n: int) -> jax.Array:
    """Squared PAA lower bound (n/w)*||q_paa - s_paa||^2 (tighter than MINDIST)."""
    w = q_paa.shape[-1]
    d = q_paa - s_paa
    return (n / w) * jnp.sum(d * d, axis=-1)


# ---------------------------------------------------------------------------
# iSAX word ordering.  MESSI partitions series into root subtrees keyed by the
# first bit of every segment; deeper tree levels refine one segment's
# cardinality at a time.  The TPU-native equivalent is a single sort by the
# *bit-interleaved* iSAX word (MSB of every segment first, then the next bit,
# ...), which clusters exactly like a breadth-first iSAX tree: the top w bits
# reproduce the root partition, each further w-bit group is one refinement
# level.  See DESIGN.md §2/§4.
# ---------------------------------------------------------------------------

def interleaved_keys(sax: jax.Array, w: int = W, bits: int = 8) -> tuple[jax.Array, ...]:
    """Pack the bit-interleaved iSAX word of each series into uint32 sort keys.

    sax: (..., w) int32 symbols (bits-wide). Returns ceil(w*bits/32) uint32
    keys, most-significant key first.
    """
    if w > 32:
        raise ValueError("w > 32 unsupported")
    per_key = max(1, 32 // w)           # bit-levels per uint32 key
    keys = []
    for k0 in range(0, bits, per_key):
        key = jnp.zeros(sax.shape[:-1], dtype=jnp.uint32)
        for j in range(min(per_key, bits - k0)):
            level = k0 + j              # bit level (0 = MSB)
            bit = (sax >> (bits - 1 - level)) & 1
            for seg in range(w):
                shift = (min(per_key, bits - k0) - 1 - j) * w + (w - 1 - seg)
                key = key | (bit[..., seg].astype(jnp.uint32) << shift)
        keys.append(key)
    return tuple(keys)


def sort_order(sax: jax.Array, w: int = W, bits: int = 8) -> jax.Array:
    """Permutation sorting series by their bit-interleaved iSAX word."""
    keys = interleaved_keys(sax, w, bits)
    # jnp.lexsort: last key is the primary one.
    return jnp.lexsort(tuple(reversed(keys)))
