"""Shared top-k frontier: the k-NN generalization of the BSF (DESIGN.md §4a).

ParIS+ and MESSI answer exact k-NN queries: every worker maintains a
k-element best-so-far priority structure and prunes against the k-th best
distance.  This module is that structure, TPU-native: a fixed-size,
per-query, always-sorted (distance, id) table that lives inside jit'd
loops as a plain pytree.  All four search paths (MESSI query-major /
block-major, ParIS flat scan, UCR brute force) and the distributed
two-round protocol carry a ``Frontier`` instead of a scalar BSF.

Invariants (property-tested in tests/test_topk.py):
  * rows are sorted ascending by (distance, id) — ties break toward the
    smaller id, matching a ``jax.lax.top_k`` brute-force oracle over an
    id-ordered distance matrix;
  * ids are unique per row; empty slots are (INF, -1);
  * ``threshold()`` (the k-th best distance) only ever decreases under
    ``insert``/``merge``, so pruning with ``lb >= threshold()`` keeps the
    no-false-dismissal guarantee for every k: a candidate can only be
    skipped once k strictly better answers are already held.

``QuerySetup`` owns the query-side preparation that used to be
copy-pasted across the search paths: z-normalization, PAA, the stage-A
approximate seeding (best-envelope block refinement) and the work-stats
initialization.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import isax
from repro.kernels import ops

INF = jnp.float32(jnp.finfo(jnp.float32).max)
_PAD_ID_KEY = jnp.int32(jnp.iinfo(jnp.int32).max)   # sort key for id < 0


class SearchStats(NamedTuple):
    """Work counters, per query — the quantities behind the paper's Fig. 9/12."""
    blocks_visited: jax.Array    # envelopes that survived pruning & were refined
    series_refined: jax.Array    # real-distance computations performed
    lb_series: jax.Array         # per-series lower bounds computed
    iters: jax.Array             # while_loop trips (scalar, shared)


def stats_init(qn: int) -> SearchStats:
    # three separate zeros buffers, NOT one shared array: the counters
    # ride inside engine.PreparedSearch, which engine.run donates —
    # aliased leaves would be the same buffer donated twice
    return SearchStats(blocks_visited=jnp.zeros((qn,), jnp.int32),
                       series_refined=jnp.zeros((qn,), jnp.int32),
                       lb_series=jnp.zeros((qn,), jnp.int32),
                       iters=jnp.zeros((), jnp.int32))


class Frontier(NamedTuple):
    """Per-query top-k result set. dists/ids (Q, K), ascending by (dist, id)."""
    dists: jax.Array   # (Q, K) f32 squared (or any monotone) distances
    ids: jax.Array     # (Q, K) int32 original series ids; -1 = empty slot

    @property
    def k(self) -> int:
        return self.dists.shape[-1]

    def threshold(self) -> jax.Array:
        """(Q,) k-th best distance — the pruning bound. INF until full."""
        return self.dists[..., -1]

    def insert(self, d: jax.Array, ids: jax.Array) -> "Frontier":
        return insert_batch(self, d, ids)

    def insert_topk(self, d: jax.Array, ids: jax.Array) -> "Frontier":
        return insert_topk(self, d, ids)

    def merge(self, other: "Frontier") -> "Frontier":
        return merge(self, other)


def init(qn: int, k: int) -> Frontier:
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    return Frontier(dists=jnp.full((qn, k), INF, jnp.float32),
                    ids=jnp.full((qn, k), -1, jnp.int32))


def _topk_by_dist_id(d: jax.Array, ids: jax.Array, k: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Ascending (distance, id)-lexicographic top-k along the last axis.

    The id tiebreak makes the result deterministic and equal to
    ``lax.top_k`` over an id-ordered distance row; ids < 0 sort last
    among equal distances.
    """
    key_id = jnp.where(ids >= 0, ids, _PAD_ID_KEY)
    order = jnp.lexsort((key_id, d), axis=-1)[..., :k]
    return (jnp.take_along_axis(d, order, axis=-1),
            jnp.take_along_axis(ids, order, axis=-1))


def insert_batch(f: Frontier, d: jax.Array, ids: jax.Array, *,
                 assume_unique: bool = False) -> Frontier:
    """Fold a batch of candidates (Q, M) into the frontier. Pure, O(K+M) sort.

    Candidates with id < 0 are ignored.  A candidate whose id is already
    held (re-visits: the stage-A block re-scanned by the main loop) keeps
    one slot, at the MIN of both distances — recomputing the same pair
    under a different gather shape can differ in the last ulps, and the
    scalar-BSF code took the min, so this preserves its k=1 output
    exactly.  Within one batch ids must be distinct — true for every
    caller, since blocks/chunks/shards partition the series.

    ``assume_unique=True`` skips the O(Q*M*K) duplicate mask for callers
    whose candidates provably cannot collide with held ids (the UCR scan:
    globally unique ids, each seen once; the shard merge: disjoint
    shards into an empty frontier).
    """
    d = jnp.where(ids >= 0, d.astype(jnp.float32), INF)
    if not assume_unique:
        same = (ids[..., :, None] == f.ids[..., None, :]) \
            & (ids[..., :, None] >= 0)                       # (Q, M, K)
        held = jnp.min(jnp.where(same, d[..., :, None], INF), axis=-2)
        f = f._replace(dists=jnp.minimum(f.dists, held))
        d = jnp.where(jnp.any(same, axis=-1), INF, d)
    all_d = jnp.concatenate([f.dists, d], axis=-1)
    all_i = jnp.concatenate([f.ids, ids], axis=-1)
    nd, ni = _topk_by_dist_id(all_d, all_i, f.k)
    return Frontier(dists=nd, ids=jnp.where(nd < INF, ni, -1))


def insert_topk(f: Frontier, d: jax.Array, ids: jax.Array) -> Frontier:
    """Fold PRE-SELECTED candidates (Q, k'), k' <= K, into the frontier.

    The fast path behind ``ops.block_topk`` / ``ops.fused_panel_topk``:
    the kernel already reduced the (Q, C) panel to its (dist, id)-lex
    top-k, so the merge sorts K + k' <= 2K elements instead of K + C.

    Exactness: inserting only the (dist, id)-lex top-k of a batch (ids
    distinct within the batch) is bit-identical to inserting the whole
    batch.  Any unselected candidate has >= k candidates strictly
    (dist, id)-before it in the SAME batch, each of which lands in the
    result or loses only to something even better — so the unselected
    candidate could never reach the table; and its duplicate-min side
    effect on a held id is dominated the same way (the held entry it
    would lower is itself lex-before it).  Hence every block-major site
    keeps PR-4/PR-5 golden parity by construction.
    """
    if d.shape[-1] > f.k:
        raise ValueError(
            f"insert_topk expects pre-selected candidates: got "
            f"{d.shape[-1]} > k={f.k}; use insert_batch for full panels")
    return insert_batch(f, d, ids)


def merge(fa: Frontier, fb: Frontier) -> Frontier:
    """Merge two frontiers (e.g. per-shard results) into one top-k."""
    return insert_batch(fa, fb.dists, fb.ids)


def result_dists(f: Frontier) -> jax.Array:
    """(Q, K) sqrt'd distances for a SearchResult; empty slots stay INF."""
    return jnp.where(f.ids >= 0, jnp.sqrt(f.dists), INF)


def bound(f: Frontier, initial_threshold: jax.Array | None = None
          ) -> jax.Array:
    """(Q,) pruning bound: k-th best so far, tightened by a seeded
    threshold (the distributed protocol's round-1 global reduce)."""
    t = f.threshold()
    if initial_threshold is not None:
        t = jnp.minimum(t, initial_threshold)
    return t


def all_gather_merge(f: Frontier, axis_names) -> Frontier:
    """Inside shard_map: merge every shard's frontier into the global top-k.

    One (D, Q, K) all-gather + one local sort per shard — communication
    independent of dataset size (the round-2 exchange of DESIGN.md §6).
    """
    gd = jax.lax.all_gather(f.dists, axis_names)   # (D, Q, K)
    gi = jax.lax.all_gather(f.ids, axis_names)
    qn, k = f.dists.shape
    return insert_batch(init(qn, k),
                        jnp.moveaxis(gd, 0, 1).reshape(qn, -1),
                        jnp.moveaxis(gi, 0, 1).reshape(qn, -1),
                        assume_unique=True)        # shards are disjoint


def query_block_l2(q: jax.Array, blocks: jax.Array) -> jax.Array:
    """Per-query distances to its own gathered block(s).

    q (Q, n); blocks (Q, ..., C, n) -> (Q, ..., C) squared distances, using
    the same expanded form as the MXU kernel (einsum keeps it fused).
    """
    qq = jnp.sum(q * q, axis=-1)                              # (Q,)
    xx = jnp.sum(blocks * blocks, axis=-1)                    # (Q, ..., C)
    cross = jnp.einsum("qn,q...n->q...", q, blocks)
    extra = xx.ndim - 1
    qq = qq.reshape(qq.shape + (1,) * extra)
    return jnp.maximum(qq + xx - 2.0 * cross, 0.0)


def approximate(index, q: jax.Array, q_paa: jax.Array, k: int = 1
                ) -> tuple[Frontier, jax.Array]:
    """Stage A: seed a frontier from each query's best-envelope block.

    Returns (frontier, block_lb (Q, B)).  One lower-bound kernel pass over
    all block envelopes, then one batched L2 against the argmin block —
    the paper's "search the tree for the query's leaf, compute real
    distances in it, store the minimum in the BSF", generalized to k.
    """
    block_lb = ops.lb_scan_planar(q_paa, index.elo, index.ehi, n=index.n)
    b0 = jnp.argmin(block_lb, axis=1)                         # (Q,)
    blocks = index.raw[b0]                                    # (Q, C, n)
    d = query_block_l2(q, blocks)                             # (Q, C)
    f = init(q.shape[0], k).insert(d, index.ids[b0])
    return f, block_lb


class QuerySetup(NamedTuple):
    """Shared query-side prep for every search path."""
    q: jax.Array                 # (Q, n) prepared (z-normed / cast) queries
    q_paa: jax.Array | None      # (Q, w) PAA, when an index is involved
    frontier: Frontier           # stage-A-seeded (or empty) top-k frontier
    block_lb: jax.Array | None   # (Q, B) stage-A envelope lower bounds
    stats: SearchStats


def prepare(queries: jax.Array, k: int, *, index=None, w: int | None = None,
            normalize: bool = True) -> QuerySetup:
    """z-norm/PAA + stage-A seeding + stats init.

    ``index``: a BlockIndex enables stage-A approximate seeding.  ``w``:
    compute PAA without an index (ParIS flat scan without a block view).
    """
    q = (isax.znorm(queries) if normalize else queries).astype(jnp.float32)
    qn = q.shape[0]
    q_paa = block_lb = None
    if index is not None and not index.device_resident:
        raise ValueError(
            "index raw series are not device-resident (opened out-of-core "
            "via storage.open_index); use repro.storage.ooc_search, or "
            "storage.load_index for the in-memory paths")
    if index is not None:
        q_paa = isax.paa(q, index.w)
        front, block_lb = approximate(index, q, q_paa, k)
    else:
        if w is not None:
            q_paa = isax.paa(q, w)
        front = init(qn, k)
    return QuerySetup(q=q, q_paa=q_paa, frontier=front, block_lb=block_lb,
                      stats=stats_init(qn))
