"""ParIS/ParIS+-style query answering: flat SAX-array lower-bound scan.

Paper mapping: "lower bound calculation workers compute the lower bound
distances between the query and the iSAX summary of EACH data series in the
dataset (stored in the SAX array), and prune ... the series that are not
pruned are stored in a candidate list, which real distance calculation
workers consume in parallel".

TPU adaptation: the LB scan over the whole array is one Pallas kernel pass
(the most SIMD-friendly phase of the paper — it is why ParIS exists).  The
candidate list becomes a chunked lax.scan with a conditional refine per chunk
(a chunk with no survivors is skipped wholesale), carrying the running BSF —
the analogue of the workers' shared-BSF updates.  No ordering, no envelopes:
the structural contrast with MESSI (search.py) is exactly the paper's.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import isax
from repro.core.index import BlockIndex, FlatIndex, flat_view
from repro.core.search import INF, SearchStats, SearchResult, approximate_search
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("chunk",))
def search_flat(index: FlatIndex, queries: jax.Array, *,
                block_index: BlockIndex | None = None,
                initial_bsf: jax.Array | None = None,
                chunk: int = 4096) -> SearchResult:
    """Exact 1-NN via the ParIS algorithm. queries (Q, n)."""
    q = isax.znorm(queries).astype(jnp.float32)
    q_paa = isax.paa(q, index.w)
    npad, n = index.raw.shape
    qn = q.shape[0]
    c = min(chunk, npad)
    pad = (-npad) % c

    lo, hi, raw, ids = index.lo, index.hi, index.raw, index.ids
    if pad:
        lo = jnp.concatenate([lo, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        hi = jnp.concatenate([hi, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        raw = jnp.concatenate(
            [raw, jnp.full((pad, n), 1.0e4, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], 0)

    # Phase 1 — approximate BSF.  The paper seeds from the best leaf; we use
    # the same stage-A routine as MESSI when a block index is available, else
    # the first chunk's best real distance.
    if initial_bsf is not None:
        bsf = initial_bsf
        best = jnp.full((qn,), -2, jnp.int32)
    elif block_index is not None:
        bsf, best, _ = approximate_search(block_index, q, q_paa)
    else:
        d0 = ops.batch_l2(q, raw[:c])
        d0 = jnp.where(ids[None, :c] >= 0, d0, INF)
        j = jnp.argmin(d0, axis=1)
        bsf = jnp.take_along_axis(d0, j[:, None], 1)[:, 0]
        best = ids[j]

    # Phase 2 — the flat LB scan over the ENTIRE SAX array (one kernel pass).
    lb = ops.lb_scan_planar(q_paa, lo, hi, n=n)               # (Q, Np+pad)

    # Phase 3 — chunked candidate refinement with running BSF.
    nchunks = raw.shape[0] // c
    raw_c = raw.reshape(nchunks, c, n)
    ids_c = ids.reshape(nchunks, c)
    lb_c = lb.reshape(qn, nchunks, c)

    def step(carry, inp):
        bsf_i, best_i, refined = carry
        raw_k, ids_k, lb_k = inp                              # (C,n),(C,),(Q,C)
        act = (lb_k < bsf_i[:, None]) & (ids_k[None, :] >= 0)

        def refine(cr):
            bsf_j, best_j, refined_j = cr
            d = ops.batch_l2(q, raw_k)                        # (Q, C)
            d = jnp.where(act, d, INF)
            j = jnp.argmin(d, axis=1)
            dmin = jnp.take_along_axis(d, j[:, None], 1)[:, 0]
            better = dmin < bsf_j
            return (jnp.where(better, dmin, bsf_j),
                    jnp.where(better, ids_k[j], best_j),
                    refined_j + jnp.sum(act, axis=1, dtype=jnp.int32))

        carry = jax.lax.cond(jnp.any(act), refine, lambda cr: cr,
                             (bsf_i, best_i, refined))
        return carry, None

    (bsf, best, refined), _ = jax.lax.scan(
        step, (bsf, best, jnp.zeros((qn,), jnp.int32)),
        (raw_c, ids_c, jnp.moveaxis(lb_c, 1, 0)))

    stats = SearchStats(
        blocks_visited=jnp.full((qn,), nchunks, jnp.int32),
        series_refined=refined,
        lb_series=jnp.full((qn,), index.n_real, jnp.int32),   # whole array
        iters=jnp.asarray(nchunks, jnp.int32),
    )
    return SearchResult(dist=jnp.sqrt(bsf), idx=best, stats=stats)


def search_paris(index: BlockIndex, queries: jax.Array, *,
                 chunk: int = 4096,
                 initial_bsf: jax.Array | None = None) -> SearchResult:
    """Convenience: run the ParIS algorithm against a BlockIndex's flat view."""
    return search_flat(flat_view(index), queries, block_index=index,
                       chunk=chunk, initial_bsf=initial_bsf)
