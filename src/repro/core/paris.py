"""ParIS/ParIS+-style query answering: flat SAX-array lower-bound scan.

Paper mapping: "lower bound calculation workers compute the lower bound
distances between the query and the iSAX summary of EACH data series in the
dataset (stored in the SAX array), and prune ... the series that are not
pruned are stored in a candidate list, which real distance calculation
workers consume in parallel".

TPU adaptation (the ``flat`` schedule of core/engine.py): the LB scan over
the whole array is one Pallas kernel pass (the most SIMD-friendly phase of
the paper — it is why ParIS exists).  The candidate list becomes a chunked
lax.scan with a conditional refine per chunk (a chunk with no survivors is
skipped wholesale), carrying the running top-k Frontier — the analogue of
the workers' shared k-NN BSF updates; pruning is against the frontier's
k-th-best distance (DESIGN.md §4a).  No ordering, no envelopes: the
structural contrast with MESSI (search.py) is exactly the paper's.
"""
from __future__ import annotations

import jax

from repro.core import engine
from repro.core.engine import ED, QueryPlan
from repro.core.index import BlockIndex, FlatIndex, flat_view
from repro.core.search import SearchResult


def search_flat(index: FlatIndex, queries: jax.Array, *, k: int = 1,
                block_index: BlockIndex | None = None,
                initial_threshold: jax.Array | None = None,
                chunk: int = 4096) -> SearchResult:
    """Exact k-NN via the ParIS algorithm. queries (Q, n).

    ``block_index`` (optional) enables the paper's approximate phase:
    stage-A seeding from the best-envelope block; without it the scan
    starts from an empty frontier.
    """
    plan = QueryPlan(metric=ED(), schedule="flat", k=k, chunk=chunk)
    return engine.run_flat(index, queries, plan, block_index,
                           initial_threshold)


def search_paris(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                 chunk: int = 4096,
                 initial_threshold: jax.Array | None = None) -> SearchResult:
    """Convenience: run the ParIS algorithm against a BlockIndex's flat view."""
    return search_flat(flat_view(index), queries, k=k, block_index=index,
                       chunk=chunk, initial_threshold=initial_threshold)
