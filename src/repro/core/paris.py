"""ParIS/ParIS+-style query answering: flat SAX-array lower-bound scan.

Paper mapping: "lower bound calculation workers compute the lower bound
distances between the query and the iSAX summary of EACH data series in the
dataset (stored in the SAX array), and prune ... the series that are not
pruned are stored in a candidate list, which real distance calculation
workers consume in parallel".

TPU adaptation: the LB scan over the whole array is one Pallas kernel pass
(the most SIMD-friendly phase of the paper — it is why ParIS exists).  The
candidate list becomes a chunked lax.scan with a conditional refine per chunk
(a chunk with no survivors is skipped wholesale), carrying the running top-k
Frontier — the analogue of the workers' shared k-NN BSF updates; pruning is
against the frontier's k-th-best distance (DESIGN.md §4a).  No ordering, no
envelopes: the structural contrast with MESSI (search.py) is exactly the
paper's.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.frontier import INF
from repro.core.index import BlockIndex, FlatIndex, flat_view
from repro.core.search import SearchResult, SearchStats
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def search_flat(index: FlatIndex, queries: jax.Array, *, k: int = 1,
                block_index: BlockIndex | None = None,
                initial_threshold: jax.Array | None = None,
                chunk: int = 4096) -> SearchResult:
    """Exact k-NN via the ParIS algorithm. queries (Q, n)."""
    setup = frontier_lib.prepare(queries, k, index=block_index, w=index.w)
    q, q_paa = setup.q, setup.q_paa
    npad, n = index.raw.shape
    qn = q.shape[0]
    c = min(chunk, npad)
    pad = (-npad) % c

    lo, hi, raw, ids = index.lo, index.hi, index.raw, index.ids
    if pad:
        lo = jnp.concatenate([lo, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        hi = jnp.concatenate([hi, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        raw = jnp.concatenate(
            [raw, jnp.full((pad, n), 1.0e4, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], 0)

    # Phase 1 — approximate top-k frontier.  The paper seeds from the best
    # leaf; prepare() ran the same stage-A routine as MESSI when a block
    # index is available, else the scan starts from an empty frontier (the
    # first chunk is then refined in full, which seeds it).

    # Phase 2 — the flat LB scan over the ENTIRE SAX array (one kernel pass).
    lb = ops.lb_scan_planar(q_paa, lo, hi, n=n)               # (Q, Np+pad)

    # Phase 3 — chunked candidate refinement with the running frontier.
    nchunks = raw.shape[0] // c
    raw_c = raw.reshape(nchunks, c, n)
    ids_c = ids.reshape(nchunks, c)
    lb_c = lb.reshape(qn, nchunks, c)

    def step(carry, inp):
        front, refined = carry
        raw_k, ids_k, lb_k = inp                              # (C,n),(C,),(Q,C)
        thr = frontier_lib.bound(front, initial_threshold)
        act = (lb_k < thr[:, None]) & (ids_k[None, :] >= 0)

        def refine(cr):
            front_j, refined_j = cr
            d = ops.batch_l2(q, raw_k)                        # (Q, C)
            d = jnp.where(act, d, INF)
            front_n = front_j.insert(d, jnp.where(act, ids_k[None, :], -1))
            return (front_n,
                    refined_j + jnp.sum(act, axis=1, dtype=jnp.int32))

        carry = jax.lax.cond(jnp.any(act), refine, lambda cr: cr,
                             (front, refined))
        return carry, None

    (front, refined), _ = jax.lax.scan(
        step, (setup.frontier, jnp.zeros((qn,), jnp.int32)),
        (raw_c, ids_c, jnp.moveaxis(lb_c, 1, 0)))

    stats = SearchStats(
        blocks_visited=jnp.full((qn,), nchunks, jnp.int32),
        series_refined=refined,
        lb_series=jnp.full((qn,), index.n_real, jnp.int32),   # whole array
        iters=jnp.asarray(nchunks, jnp.int32),
    )
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


def search_paris(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                 chunk: int = 4096,
                 initial_threshold: jax.Array | None = None) -> SearchResult:
    """Convenience: run the ParIS algorithm against a BlockIndex's flat view."""
    return search_flat(flat_view(index), queries, k=k, block_index=index,
                       chunk=chunk, initial_threshold=initial_threshold)
