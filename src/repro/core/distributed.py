"""Multi-device / multi-pod index build and query answering (DESIGN.md §6).

The paper's worker threads become mesh devices.  Every device is symmetric
(as every core is in the paper): the dataset is range-sharded over ALL mesh
axes flattened, each device builds its own BlockIndex shard completely
independently (the paper's "workers process distinct subtrees ... no need for
synchronization"), and query answering is the two-round shared-frontier
protocol (the k-NN generalization of the paper's shared BSF), wrapped
around an arbitrary ``engine.QueryPlan`` — any metric, either ordered
schedule, either backend:

  round 1: every shard seeds its approximate top-k frontier (stage A) ->
           pmin all-reduce of the k-th-best distance (one scalar per
           query).  The min over shards of the local k-th best upper
           bounds the GLOBAL k-th-NN distance (any one shard already
           holds k candidates at least that good), so it is a valid
           shared pruning threshold for every shard.
  round 2: every shard runs the exact ordered-pruning search seeded with
           that global threshold (so pruning is as tight as the paper's
           shared-memory BSF reads), producing its local top-k frontier;
           an all-gather + frontier merge (core/frontier.py) then yields
           the identical global top-k on every shard.

``search_sharded`` runs the protocol inside one shard_map over
device-resident shards; ``search_sharded_ooc`` runs the SAME two rounds
at the host level over out-of-core shards (one ``storage.SearchSession``
per shard — the paper's multi-node on-disk deployment), with the pmin
becoming an np.minimum reduce between the stage-A pass and the walks.

Total communication per query batch: one (Q,) scalar all-reduce + one
(Q, K) frontier all-gather — independent of dataset size, which is what
makes this design runnable at 1000+ nodes.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.index as index_lib
from repro.compat import shard_map
from repro.core import engine
from repro.core import frontier as frontier_lib
from repro.core.frontier import Frontier
from repro.core.index import BlockIndex
from repro.core.search import SearchResult, SearchStats


def _all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def index_pspecs(mesh: Mesh, like: BlockIndex | None = None,
                 **meta: Any) -> BlockIndex:
    """PartitionSpecs for each BlockIndex field (shard over all axes).

    shard_map spec pytrees must carry the same static metadata as the real
    index, so pass either ``like`` (an existing index) or explicit meta.
    """
    ax = _all_axes(mesh)
    if like is not None:
        meta = dict(n=like.n, w=like.w, card=like.card,
                    capacity=like.capacity, n_real=like.n_real)
    return BlockIndex(
        raw=P(ax), slo=P(ax), shi=P(ax),
        elo=P(None, ax), ehi=P(None, ax), ids=P(ax), **meta)


def build_sharded(raw: jax.Array, mesh: Mesh, *, w: int = 16, card: int = 256,
                  capacity: int = 512, normalize: bool = True) -> BlockIndex:
    """Build one index shard per device from globally-sharded raw data.

    raw (N, n) with N divisible by the device count.  Each shard's series
    keep their GLOBAL ids so query answers are mesh-shape-independent.
    """
    ax = _all_axes(mesh)
    n_series, n = raw.shape
    n_dev = mesh.size
    if n_series % n_dev:
        raise ValueError(f"N={n_series} must divide device count {n_dev}")
    shard_n = n_series // n_dev
    cap = min(capacity, shard_n)
    ids = jnp.arange(n_series, dtype=jnp.int32)

    def _build(local_raw, local_ids):
        return index_lib.build(local_raw, w=w, card=card, capacity=capacity,
                               normalize=normalize, ids=local_ids)

    out_specs = index_pspecs(mesh, n=n, w=w, card=card, capacity=cap,
                             n_real=shard_n)
    fn = shard_map(_build, mesh=mesh, in_specs=(P(ax), P(ax)),
                       out_specs=out_specs)
    return fn(raw, ids)


def _merge_shards(res, ax) -> tuple[jax.Array, jax.Array]:
    """All-gather per-shard (Q, K) results and merge into the global top-k.

    Merging happens in the sqrt-distance domain (monotone, so the
    (dist, id) order is unchanged); empty local slots carry id -1 and are
    dropped by the frontier insert.
    """
    f_g = frontier_lib.all_gather_merge(Frontier(res.dist, res.idx), ax)
    return f_g.dists, f_g.ids


def search_sharded(sharded_index: BlockIndex, queries: jax.Array, mesh: Mesh,
                   *, k: int = 1, blocks_per_iter: int = 4,
                   lb_filter: bool = True,
                   deadline_blocks: int | None = None,
                   schedule: str = "block_major",
                   metric=None) -> SearchResult:
    """Exact global k-NN over all shards. queries (Q, n) replicated.

    The two-round protocol wrapped around an ``engine.QueryPlan``:
    ``schedule`` picks "block_major" (optimized batched schedule, the
    production default) or "query_major" (the paper-faithful priority-
    queue order, kept as the measured baseline); ``metric`` overrides
    the metric axis (default z-normed ``ED`` — pass ``engine.Cosine()``
    for a sharded vector index built with ``normalize=False``).
    """
    ax = _all_axes(mesh)
    specs = index_pspecs(mesh, like=sharded_index)
    m = engine.ED(lb_filter=lb_filter) if metric is None else metric
    plan = engine.QueryPlan(metric=m, schedule=schedule, k=k,
                            blocks_per_iter=blocks_per_iter,
                            deadline_blocks=deadline_blocks)

    def _search(local_index, q):
        # round 1: local approximate top-k -> global k-th-best all-reduce
        prep = engine.prepare(m, local_index, q, k)
        thr_g = jax.lax.pmin(prep.front.threshold(), ax)
        # round 2: resume from the round-1 prepared state, seeded with
        # the global threshold — query prep, block ranking, and stage A
        # are reused, not recomputed (previously this leaned on XLA CSE
        # to dedup the second engine.prepare inside the shard_map trace)
        res = engine.run(local_index, q, plan, initial_threshold=thr_g,
                         prepared=prep)
        # merge: all-gather the (Q, K) shard frontiers -> global top-k
        dist_g, idx_g = _merge_shards(res, ax)
        stats = SearchStats(
            blocks_visited=jax.lax.psum(res.stats.blocks_visited, ax),
            series_refined=jax.lax.psum(res.stats.series_refined, ax),
            lb_series=jax.lax.psum(res.stats.lb_series, ax),
            iters=jax.lax.pmax(res.stats.iters, ax),
        )
        return SearchResult(dist=dist_g, idx=idx_g, stats=stats)

    out = SearchResult(
        dist=P(None), idx=P(None),
        stats=SearchStats(blocks_visited=P(None), series_refined=P(None),
                          lb_series=P(None), iters=P()))
    fn = shard_map(_search, mesh=mesh, in_specs=(specs, P(None)),
                       out_specs=out, check_vma=False)
    return fn(sharded_index, queries)


def search_sharded_ooc(sessions: Sequence, queries: jax.Array, *,
                       k: int = 1, lb_filter: bool = True,
                       normalize_queries: bool = True, metric=None,
                       pipeline_depth: int | None = None,
                       group_blocks: int | None = None):
    """Distributed OUT-OF-CORE exact k-NN: the same two-round protocol,
    host-level, over per-shard ``storage.SearchSession``s.

    Each session wraps one shard's on-disk index (disjoint series,
    global ids — e.g. built per shard with ``core.build(..., ids=...)``
    and persisted).  Round 1 runs stage A on every shard (fetching only
    best-envelope blocks) and min-reduces the k-th-best thresholds;
    round 2 RESUMES each shard from its round-1 prepared state
    (``storage.PreparedRound``), seeded with the global bound: the
    cached block-major walk skips query prep, block ranking, and every
    stage-A block — no block is fetched or refined twice per protocol
    run — while pruning as tightly as the shared-memory BSF would
    allow; finally the per-shard frontiers merge into the global top-k.

    Returns an ``OocSearchResult`` whose stats/io are summed over
    shards; round 1's stage-A disk reads are billed into each shard's
    round-2 IOStats (the prepared state carries them), so
    ``io.blocks_fetched`` is the protocol's FULL disk cost, directly
    comparable to running the shards blind.  -> global exact top-k,
    identical to a single out-of-core search over the union of the
    shards.  (``stats.iters`` stays 0: the cached walk does not count
    while_loop trips.)

    ``pipeline_depth``/``group_blocks`` forward to every shard's stage-A
    chain and round-2 walk (``engine.run_cached``'s pipeline knobs;
    None = each session's own default).  Answers are bit-identical at
    every setting — only speculative I/O and sync cadence change.
    """
    import numpy as np

    from repro.storage.ooc_search import IOStats, OocSearchResult

    if not sessions:
        raise ValueError("search_sharded_ooc needs at least one session")
    kw = dict(k=k, lb_filter=lb_filter, normalize_queries=normalize_queries,
              metric=metric, pipeline_depth=pipeline_depth,
              group_blocks=group_blocks)
    # round 1: per-shard stage-A prepared states -> host pmin of thresholds
    preps = [s.approximate_threshold(queries, **kw) for s in sessions]
    thr_g = jnp.asarray(np.minimum.reduce([p.threshold for p in preps]))
    # round 2: per-shard walks resumed from round 1, seeded with the bound
    results = [s.search(queries, initial_threshold=thr_g, prepared=p, **kw)
               for s, p in zip(sessions, preps)]
    # merge: per-shard frontiers (sqrt domain, disjoint ids) -> global top-k
    front = Frontier(results[0].dist, results[0].idx)
    for r in results[1:]:
        front = frontier_lib.merge(front, Frontier(r.dist, r.idx))
    stats = SearchStats(
        blocks_visited=functools.reduce(
            jnp.add, [r.stats.blocks_visited for r in results]),
        series_refined=functools.reduce(
            jnp.add, [r.stats.series_refined for r in results]),
        lb_series=functools.reduce(
            jnp.add, [r.stats.lb_series for r in results]),
        iters=functools.reduce(
            jnp.maximum, [r.stats.iters for r in results]),
    )
    io = IOStats(
        bytes_read=sum(r.io.bytes_read for r in results),
        bytes_scan=sum(r.io.bytes_scan for r in results),
        blocks_fetched=sum(r.io.blocks_fetched for r in results),
        blocks_total=sum(r.io.blocks_total for r in results),
        cache_hits=sum(r.io.cache_hits for r in results),
        blocks_refined=sum(r.io.blocks_refined for r in results),
    )
    return OocSearchResult(dist=front.dists, idx=front.ids,
                           stats=stats, io=io)


def search_sharded_scan(raw: jax.Array, queries: jax.Array, mesh: Mesh,
                        *, k: int = 1, chunk: int = 4096) -> SearchResult:
    """Distributed UCR-Suite-p brute force (baseline + oracle), same merge."""
    from repro.core import ucr
    ax = _all_axes(mesh)
    n_series = raw.shape[0]
    ids = jnp.arange(n_series, dtype=jnp.int32)

    def _scan(local_raw, local_ids, q):
        res = ucr.search_scan(local_raw, q, k=k,
                              chunk=min(chunk, local_raw.shape[0]),
                              ids=local_ids)
        return _merge_shards(res, ax)

    fn = shard_map(_scan, mesh=mesh, in_specs=(P(ax), P(ax), P(None)),
                       out_specs=(P(None), P(None)), check_vma=False)
    dist, idx = fn(raw, ids, queries)
    qn = queries.shape[0]
    stats = SearchStats(
        blocks_visited=jnp.zeros((qn,), jnp.int32),
        series_refined=jnp.full((qn,), n_series, jnp.int32),
        lb_series=jnp.zeros((qn,), jnp.int32),
        iters=jnp.zeros((), jnp.int32))
    return SearchResult(dist=dist, idx=idx, stats=stats)
