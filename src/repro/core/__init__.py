"""Core library: the paper's contribution (parallel iSAX indexing for exact
similarity search — ParIS / ParIS+ / MESSI), TPU-native. See DESIGN.md."""
from repro.core import frontier, isax
from repro.core.frontier import Frontier, QuerySetup, SearchStats
from repro.core.index import BlockIndex, FlatIndex, build, build_flat, flat_view
from repro.core.search import SearchResult, search
from repro.core.paris import search_flat, search_paris
from repro.core.ucr import search_scan

__all__ = [
    "frontier", "isax", "Frontier", "QuerySetup", "BlockIndex", "FlatIndex",
    "build", "build_flat", "flat_view", "SearchResult", "SearchStats",
    "search", "search_flat", "search_paris", "search_scan",
]
