"""Core library: the paper's contribution (parallel iSAX indexing for exact
similarity search — ParIS / ParIS+ / MESSI), TPU-native. See DESIGN.md."""
from repro.core import engine, frontier, isax
from repro.core.engine import DTW, Cosine, ED, QueryPlan
from repro.core.frontier import Frontier, QuerySetup, SearchStats
from repro.core.index import BlockIndex, FlatIndex, build, build_flat, flat_view
from repro.core.search import SearchResult, search, search_block_major
from repro.core.paris import search_flat, search_paris
from repro.core.ucr import search_scan

__all__ = [
    "engine", "frontier", "isax", "QueryPlan", "ED", "DTW", "Cosine",
    "Frontier", "QuerySetup", "BlockIndex", "FlatIndex",
    "build", "build_flat", "flat_view", "SearchResult", "SearchStats",
    "search", "search_block_major", "search_flat", "search_paris",
    "search_scan",
]
