"""DTW similarity search — the paper's §V extension ("no changes are required
in the index structure: we can index a dataset once, and then use this index
to answer both Euclidean and DTW similarity search queries").

The DTW machinery now lives in `core/engine.py` as the ``DTW(r)`` metric
adapter — this module keeps the stable public faces:

  * exact banded DTW (`dtw_band`: anti-diagonal lax.scan — the standard
    way to vectorize the DP on SIMD machines, and on the VPU);
  * the LB_Keogh family (`query_envelope`, `lb_keogh`) and the
    index-level bound (`envelope_block_lb`): envelope-widened region
    MINDIST keeps the no-false-dismissal guarantee, so the SAME
    BlockIndex answers DTW queries;
  * `search_dtw`, a `DTW(r)` query plan on the paper-faithful
    query-major schedule, and `search_dtw_flat`, the same metric on the
    ParIS flat scan (DTW x flat cell of the matrix).  Out-of-core DTW is
    the same metric on the cached backend:
    ``storage.SearchSession.search(qs, metric=DTW(r))``.
"""
from __future__ import annotations

import jax

from repro.core import engine
from repro.core.engine import (DTW, QueryPlan, dtw_band, lb_keogh,  # noqa: F401
                               query_envelope)
from repro.core.index import BlockIndex, FlatIndex
from repro.core.search import INF, SearchResult  # noqa: F401


def envelope_block_lb(index: BlockIndex, u_paa: jax.Array, l_paa: jax.Array
                      ) -> jax.Array:
    """(Q, B) squared lower bound of DTW against any series in each block.

    MINDIST between the interval [l_paa, u_paa] and the block envelope
    [elo, ehi]: zero when they overlap, gap^2 otherwise — which lower-bounds
    LB_Keogh_PAA and hence DTW.
    """
    return engine.interval_planar_lb(u_paa, l_paa, index.elo, index.ehi,
                                     n=index.n)


def search_dtw(index: BlockIndex, queries: jax.Array, *, r: int, k: int = 1,
               blocks_per_iter: int = 2,
               deadline_blocks: int | None = None) -> SearchResult:
    """Exact DTW k-NN using the unchanged Euclidean BlockIndex.

    Carries the same top-k Frontier as the Euclidean paths; pruning is
    against the k-th best DTW distance so far (squared domain).  Work
    stats keep their historical DTW meaning on every backend
    (``DTW.finalize_stats``): every visited block costs a full panel of
    LB_Keogh bounds AND a full panel of banded-DP distances (the DP is
    computed for all candidates, then masked), so
    ``series_refined == lb_series == blocks_visited * capacity``.
    ``deadline_blocks`` caps refined blocks per query (anytime answers /
    straggler mitigation, same semantics as ``search.search``; None =
    exact) — DTW's banded DP is the costliest refine in the matrix, so
    the deadline matters most here.
    """
    plan = QueryPlan(metric=DTW(r=r), schedule="query_major", k=k,
                     blocks_per_iter=blocks_per_iter,
                     deadline_blocks=deadline_blocks)
    return engine.run(index, queries, plan)


def search_dtw_flat(index: FlatIndex, queries: jax.Array, *, r: int,
                    k: int = 1, block_index: BlockIndex | None = None,
                    chunk: int = 4096,
                    deadline_blocks: int | None = None) -> SearchResult:
    """Exact DTW k-NN on the ParIS flat schedule (DTW x flat).

    One interval-to-region MINDIST pass over the whole per-series SAX
    array, then chunked banded-DP refinement under the tightening k-th
    best bound.  ``block_index`` (optional, from the same build) enables
    stage-A seeding; the exactness argument is the ED one verbatim,
    since the planar bound lower-bounds LB_Keogh_PAA and hence DTW.
    ``deadline_blocks`` caps refined CHUNKS (the flat schedule's block
    analogue; None = exact).
    """
    plan = QueryPlan(metric=DTW(r=r), schedule="flat", k=k, chunk=chunk,
                     deadline_blocks=deadline_blocks)
    return engine.run_flat(index, queries, plan, block_index)
