"""DTW similarity search — the paper's §V extension ("no changes are required
in the index structure: we can index a dataset once, and then use this index
to answer both Euclidean and DTW similarity search queries").

Pieces:
  * exact DTW with a Sakoe-Chiba band, vectorized over candidates via an
    anti-diagonal lax.scan (the row-major DP has an in-row dependency; the
    anti-diagonal order removes it, which is the standard way to vectorize
    DTW on SIMD machines — and on the VPU);
  * LB_Keogh lower bound from the query envelope (U/L over the band);
  * an index-level lower bound: MINDIST between the PAA of the query envelope
    and the stored iSAX region bounds — envelope-widened regions keep the
    no-false-dismissal guarantee, so the SAME BlockIndex answers DTW queries.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.index import BlockIndex
from repro.core.search import INF, SearchStats, SearchResult
from repro.kernels import ops


def query_envelope(q: jax.Array, r: int) -> tuple[jax.Array, jax.Array]:
    """Keogh envelope: U_i = max(q[i-r:i+r+1]), L_i = min(...). q (..., n)."""
    n = q.shape[-1]
    pads = [(0, 0)] * (q.ndim - 1) + [(r, r)]
    qu = jnp.pad(q, pads, constant_values=-jnp.inf)
    ql = jnp.pad(q, pads, constant_values=jnp.inf)
    iu = jnp.arange(n)[:, None] + jnp.arange(2 * r + 1)[None, :]
    u = jnp.max(qu[..., iu], axis=-1)
    l = jnp.min(ql[..., iu], axis=-1)
    return u, l


def lb_keogh(q_env: tuple[jax.Array, jax.Array], x: jax.Array) -> jax.Array:
    """LB_Keogh(Q, x)^2 for raw candidates. u,l (Q, n); x (N, n) -> (Q, N)."""
    u, l = q_env
    above = jnp.maximum(x[None] - u[:, None], 0.0)
    below = jnp.maximum(l[:, None] - x[None], 0.0)
    d = above + below   # at most one of the two is nonzero per element
    return jnp.sum(d * d, axis=-1)


def dtw_band(a: jax.Array, b: jax.Array, r: int) -> jax.Array:
    """Exact squared-DTW with band r. a (..., n) vs b (..., n), broadcast.

    Anti-diagonal DP: diag k holds cells (i, j) with i+j == k; each diagonal
    depends only on the previous two, so the whole diagonal updates in one
    vector op. Cells outside the band are +INF.
    """
    a, b = jnp.broadcast_arrays(a, b)
    n = a.shape[-1]
    i_idx = jnp.arange(n)

    def diag_cost(k):
        # cell (i, k-i) for i in [0, n)
        j = k - i_idx
        valid = (j >= 0) & (j < n) & (jnp.abs(i_idx - j) <= r)
        jc = jnp.clip(j, 0, n - 1)
        c = (a[..., i_idx] - jnp.take(b, jc, axis=-1)) ** 2
        return jnp.where(valid, c, INF)

    # dp diagonals indexed by i (row); shifting aligns (i-1, j), (i, j-1), (i-1, j-1)
    def shift_down(d):  # d[i] -> d[i-1]
        return jnp.concatenate([jnp.full(d.shape[:-1] + (1,), INF), d[..., :-1]],
                               axis=-1)

    def body(carry, k):
        prev, prev2 = carry   # diag k-1, diag k-2 (indexed by i)
        c = diag_cost(k)
        best = jnp.minimum(jnp.minimum(prev, shift_down(prev)),
                           shift_down(prev2))
        cur = c + jnp.where(k == 0, 0.0, best)
        cur = jnp.minimum(cur, INF)   # keep +INF cells from overflowing
        return (cur, prev), None

    init_shape = a.shape[:-1] + (n,)
    prev = jnp.full(init_shape, INF)
    prev2 = jnp.full(init_shape, INF)
    (last, second), _ = jax.lax.scan(body, (prev, prev2),
                                     jnp.arange(2 * n - 1))
    return last[..., n - 1]   # cell (n-1, n-1) lives on diag 2n-2 at i=n-1


def envelope_block_lb(index: BlockIndex, u_paa: jax.Array, l_paa: jax.Array
                      ) -> jax.Array:
    """(Q, B) squared lower bound of DTW against any series in each block.

    MINDIST between the interval [l_paa, u_paa] and the block envelope
    [elo, ehi]: zero when they overlap, gap^2 otherwise — which lower-bounds
    LB_Keogh_PAA and hence DTW. Uses the planar lb kernel twice.
    """
    n = index.n
    # distance from interval [l, u] to interval [lo, hi] per segment:
    # max(0, lo - u, l - hi); implement with the existing kernel by querying
    # u against (lo, +S) and l against (-S, hi) and summing the pieces.
    big = isax.SENTINEL
    w, b = index.elo.shape
    above = ops.lb_scan_planar(u_paa, index.elo,
                               jnp.full((w, b), big, jnp.float32), n=n)
    below = ops.lb_scan_planar(l_paa, jnp.full((w, b), -big, jnp.float32),
                               index.ehi, n=n)
    return above + below


@functools.partial(jax.jit, static_argnames=("r", "k", "blocks_per_iter"))
def search_dtw(index: BlockIndex, queries: jax.Array, *, r: int, k: int = 1,
               blocks_per_iter: int = 2) -> SearchResult:
    """Exact DTW k-NN using the unchanged Euclidean BlockIndex.

    Carries the same top-k Frontier as the Euclidean paths; pruning is
    against the k-th best DTW distance so far (squared domain).
    """
    q = isax.znorm(queries).astype(jnp.float32)
    qn = q.shape[0]
    b, c, n = index.raw.shape
    u, l = query_envelope(q, r)
    u_paa, l_paa = isax.paa(u, index.w), isax.paa(l, index.w)

    block_lb = envelope_block_lb(index, u_paa, l_paa)          # (Q, B)

    # stage A: exact DTW against the best block seeds the frontier
    b0 = jnp.argmin(block_lb, axis=1)
    blocks0 = index.raw[b0]                                    # (Q, C, n)
    d0 = dtw_band(q[:, None, :], blocks0, r)                   # (Q, C)
    front = frontier_lib.init(qn, k).insert(d0, index.ids[b0])

    order = jnp.argsort(block_lb, axis=1)
    kb = min(blocks_per_iter, b)

    def next_lb(ptr):
        nxt = jax.lax.dynamic_slice_in_dim(order, ptr, 1, axis=1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]

    def cond(state):
        ptr, f, _ = state
        return jnp.logical_and(ptr < b, jnp.any(next_lb(ptr) < f.threshold()))

    def body(state):
        ptr, f, visited = state
        thr = f.threshold()
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, kb, axis=1)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)
        active = lbs < thr[:, None]

        def refine(cr):
            f_i, visited_i = cr
            blocks = index.raw[idxs]                           # (Q,K,C,n)
            ids = index.ids[idxs]
            # second-level filter: LB_Keogh on raw values (tighter than PAA)
            above = jnp.maximum(blocks - u[:, None, None, :], 0.0)
            below = jnp.maximum(l[:, None, None, :] - blocks, 0.0)
            dd = above + below
            lbk = jnp.sum(dd * dd, axis=-1)                    # (Q,K,C)
            s_act = (lbk < thr[:, None, None]) & active[..., None] \
                    & (ids >= 0)
            d = dtw_band(q[:, None, None, :], blocks, r)       # (Q,K,C)
            d = jnp.where(s_act, d, INF)
            f_n = f_i.insert(d.reshape(qn, -1),
                             jnp.where(s_act, ids, -1).reshape(qn, -1))
            return (f_n,
                    visited_i + jnp.sum(active, axis=1, dtype=jnp.int32))

        f_n, visited_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, visited))
        return ptr + kb, f_n, visited_n

    ptr0 = jnp.zeros((), jnp.int32)
    visited0 = jnp.zeros((qn,), jnp.int32)
    _, front, visited = jax.lax.while_loop(
        cond, body, (ptr0, front, visited0))

    stats = SearchStats(blocks_visited=visited,
                        series_refined=visited * c,
                        lb_series=visited * c,
                        iters=jnp.zeros((), jnp.int32))
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)
