"""MESSI-style exact k-NN query answering, vectorized for TPU (DESIGN.md §4).

Paper mapping:
  Stage A  "search the tree for the query's leaf, compute real distances in
           it, store the minimum in BSF"            -> best-envelope block
           argmin + one batched L2 against it (frontier.approximate).
  Stage C  "surviving leaves go into priority queues ordered by lower bound;
           workers pop, stop a queue when its head's LB >= BSF"
                                                    -> per-query LB-argsorted
           block schedule + lax.while_loop that refines the next K blocks per
           iteration and exits when every query's next block LB >= its
           pruning bound.  Ordered traversal + that stopping rule ARE the
           priority-queue semantics; the heap itself is an artifact of MIMD
           threads.
  k-NN BSF "the BSF array holds the k best-so-far answers; pruning uses the
           k-th best distance"                      -> the shared top-k
           Frontier (core/frontier.py); the pruning bound is
           ``frontier.threshold()`` = the k-th best distance, so skipping
           only blocks/series with LB >= threshold can never discard a true
           k-NN member (no false dismissals, any k).
  per-series lower-bound filtering inside a leaf     -> lb_filter=True masks
           refinement to series whose own MINDIST < threshold (the stats
           expose the paper's "MESSI performs fewer real distance
           calculations" claim).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_lib
from repro.core.frontier import Frontier, INF, SearchStats, query_block_l2
from repro.core.index import BlockIndex
from repro.kernels import ops


class SearchResult(NamedTuple):
    dist: jax.Array              # (Q, K) exact k-NN Euclidean distances, ascending
    idx: jax.Array               # (Q, K) original ids; -1 = fewer than K real series
    stats: SearchStats

    @property
    def nn_dist(self) -> jax.Array:
        """(Q,) nearest-neighbour distance (the k=1 column)."""
        return self.dist[..., 0]

    @property
    def nn_idx(self) -> jax.Array:
        """(Q,) nearest-neighbour id (the k=1 column)."""
        return self.idx[..., 0]


def _result(front: Frontier, stats: SearchStats) -> SearchResult:
    """sqrt the squared frontier distances; empty slots stay (INF, -1)."""
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


_bound = frontier_lib.bound


def refine_panel(q: jax.Array, q_paa: jax.Array, front: Frontier,
                 stats: SearchStats, block: jax.Array, ids_b: jax.Array,
                 lo: jax.Array | None, hi: jax.Array | None,
                 active: jax.Array, thr: jax.Array, *, n: int, w: int,
                 lb_filter: bool) -> tuple[Frontier, SearchStats]:
    """Refine one (C, n) raw block panel against every query at once.

    The per-block unit of work shared by the in-memory block-major schedule
    and the out-of-core streaming search (storage/cache.py, which feeds it
    blocks fetched through the ``BlockIndex.host_raw`` block cache): optional
    per-series
    MINDIST filtering, one (Q, C) MXU distance panel, one frontier insert,
    and the work-stat updates.  ``active`` (Q,) masks queries whose envelope
    lower bound beat ``thr``; ``lo``/``hi`` are the block's (w, C) per-series
    bounds (unused when ``lb_filter`` is False).
    """
    qn, c = q.shape[0], block.shape[0]
    if lb_filter:
        qe = q_paa[:, :, None]                                 # (Q, w, 1)
        dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
        s_lb = (n / w) * jnp.sum(dd * dd, axis=1)              # (Q, C)
        s_act = (s_lb < thr[:, None]) & active[:, None]
    else:
        s_act = jnp.broadcast_to(active[:, None], (qn, c))
    d = ops.batch_l2(q, block)                                 # (Q, C)
    live = s_act & (ids_b >= 0)[None, :]
    d = jnp.where(live, d, INF)
    front = front.insert(d, jnp.where(live, ids_b[None, :], -1))
    stats = SearchStats(
        blocks_visited=stats.blocks_visited + active.astype(jnp.int32),
        series_refined=stats.series_refined
        + jnp.sum(live, axis=1, dtype=jnp.int32),
        lb_series=stats.lb_series
        + (active.astype(jnp.int32) * c if lb_filter else 0),
        iters=stats.iters,
    )
    return front, stats


@functools.partial(jax.jit, static_argnames=("k", "blocks_per_iter",
                                             "lb_filter", "deadline_blocks",
                                             "normalize_queries"))
def search(index: BlockIndex, queries: jax.Array, *, k: int = 1,
           blocks_per_iter: int = 4, lb_filter: bool = True,
           initial_threshold: jax.Array | None = None,
           deadline_blocks: int | None = None,
           normalize_queries: bool = True) -> SearchResult:
    """Exact k-NN for a batch of queries (Q, n) against one index shard.

    ``initial_threshold`` tightens the pruning bound (squared distance) —
    the distributed path passes the globally-reduced k-th-best approximate
    distance here (paper's shared-BSF variable); it never appears in the
    result, which always holds this shard's own top-k.
    ``deadline_blocks`` caps refined blocks per query (straggler mitigation /
    anytime answers; None = exact).
    ``normalize_queries=False`` is the generic-vector path (core/vector.py):
    the index was built with normalize=False and queries arrive prepared.
    """
    setup = frontier_lib.prepare(queries, k, index=index,
                                 normalize=normalize_queries)
    q, q_paa, front, block_lb, stats0 = setup
    b, c, n = index.raw.shape
    qn = q.shape[0]
    kb = min(blocks_per_iter, b)

    order = jnp.argsort(block_lb, axis=1)                     # (Q, B)
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def next_lb(ptr):
        # Invariant: ``cond`` evaluates this even when ptr >= max_ptr —
        # jnp.logical_and does not short-circuit — so after the final body
        # trip ptr can reach up to b + kb - 1.  The clamp keeps the slice
        # start in-bounds explicitly (the clamped value is discarded:
        # ptr < max_ptr is already False) instead of leaning on
        # dynamic_slice's implicit start clamping.
        safe = jnp.minimum(ptr, b - 1)
        nxt = jax.lax.dynamic_slice_in_dim(order, safe, 1, axis=1)  # (Q,1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]     # (Q,)

    def cond(state):
        ptr, f, _ = state
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(next_lb(ptr)
                                       < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, kb, axis=1)  # (Q,K)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)            # (Q,K)
        active = lbs < thr[:, None]                                  # (Q,K)

        def refine(carry):
            f_i, st_i = carry
            blocks = index.raw[idxs]                                # (Q,K,C,n)
            ids = index.ids[idxs]                                   # (Q,K,C)
            if lb_filter:
                lo = index.slo[idxs]                                # (Q,K,w,C)
                hi = index.shi[idxs]
                qe = q_paa[:, None, :, None]                        # (Q,1,w,1)
                dd = jnp.maximum(jnp.maximum(lo - qe, qe - hi), 0.0)
                s_lb = (n / index.w) * jnp.sum(dd * dd, axis=2)     # (Q,K,C)
                s_act = (s_lb < thr[:, None, None]) & active[..., None]
            else:
                s_act = jnp.broadcast_to(active[..., None], ids.shape)
            d = query_block_l2(q, blocks)                           # (Q,K,C)
            live = s_act & (ids >= 0)
            d = jnp.where(live, d, INF)
            f_n = f_i.insert(d.reshape(qn, -1),
                             jnp.where(live, ids, -1).reshape(qn, -1))
            st_n = SearchStats(
                blocks_visited=st_i.blocks_visited
                + jnp.sum(active, axis=1, dtype=jnp.int32),
                series_refined=st_i.series_refined
                + jnp.sum(live, axis=(1, 2), dtype=jnp.int32),
                lb_series=st_i.lb_series
                + (jnp.sum(active, axis=1, dtype=jnp.int32) * c
                   if lb_filter else 0),
                iters=st_i.iters,
            )
            return f_n, st_n

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + kb, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return _result(front, stats)


@functools.partial(jax.jit, static_argnames=("k", "lb_filter",
                                             "deadline_blocks",
                                             "normalize_queries"))
def search_block_major(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                       lb_filter: bool = True,
                       initial_threshold: jax.Array | None = None,
                       deadline_blocks: int | None = None,
                       normalize_queries: bool = True) -> SearchResult:
    """Exact k-NN with a BLOCK-major schedule (beyond-paper optimization).

    The paper's MESSI pops per-query priority queues — each thread gathers
    ITS query's next-best leaf.  For a BATCH of queries on matrix hardware
    that plan re-fetches (Q x K x C x n) raw bytes per round; the fetches,
    not the pruned distance math, dominate (measured 92 ms/query vs 11
    ms/query brute force at 50k x 256 on CPU — see EXPERIMENTS.md §Perf).

    Here the roles flip: blocks are visited ONCE each, in ascending
    min-over-queries lower-bound order; every visit is one contiguous
    ``dynamic_slice`` (no gather) plus one (Q, C) MXU panel against all
    still-active queries.  A suffix-min table over the scheduled LB matrix
    gives the exact per-query stopping rule (when suffix_min[ptr, q] >=
    threshold[q] nothing later can improve q's top-k; when that holds for
    all q we stop) — the same no-false-dismissal guarantee, O(B log B)
    schedule setup.
    """
    setup = frontier_lib.prepare(queries, k, index=index,
                                 normalize=normalize_queries)
    q, q_paa, front, block_lb, stats0 = setup
    b, c, n = index.raw.shape
    qn = q.shape[0]

    order = jnp.argsort(jnp.min(block_lb, axis=0))            # (B,)
    sched_lb = block_lb[:, order]                             # (Q, B)
    # suffix min over the schedule: can anything at >= ptr still help q?
    suffix = jax.lax.cummin(sched_lb[:, ::-1], axis=1)[:, ::-1]
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def cond(state):
        ptr, f, _ = state
        # same invariant as ``next_lb`` in ``search``: logical_and does
        # not short-circuit, so this slice is evaluated at ptr == max_ptr
        # after the final trip — clamp explicitly (the value is discarded)
        safe = jnp.minimum(ptr, b - 1)
        live = jax.lax.dynamic_slice_in_dim(suffix, safe, 1, axis=1)[:, 0]
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(live < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        b_id = order[ptr]
        lbs = jax.lax.dynamic_slice_in_dim(block_lb, b_id, 1, axis=1)[:, 0]
        active = lbs < thr                                    # (Q,)

        def refine(cr):
            f_i, st_i = cr
            block = jax.lax.dynamic_index_in_dim(index.raw, b_id, 0,
                                                 keepdims=False)   # (C, n)
            ids_b = jax.lax.dynamic_index_in_dim(index.ids, b_id, 0,
                                                 keepdims=False)   # (C,)
            lo = hi = None
            if lb_filter:
                lo = jax.lax.dynamic_index_in_dim(index.slo, b_id, 0,
                                                  keepdims=False)  # (w, C)
                hi = jax.lax.dynamic_index_in_dim(index.shi, b_id, 0,
                                                  keepdims=False)
            return refine_panel(q, q_paa, f_i, st_i, block, ids_b, lo, hi,
                                active, thr, n=n, w=index.w,
                                lb_filter=lb_filter)

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + 1, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return _result(front, stats)
