"""MESSI-style exact k-NN query answering (DESIGN.md §4).

Both schedules now live in `core/engine.py` — this module is the
Euclidean face of the engine, kept as the stable public API.  Paper
mapping (details in the engine docstrings):

  Stage A  "search the tree for the query's leaf, compute real distances
           in it, store the minimum in BSF"       -> `engine.prepare`
           (best-envelope block argmin + one batched L2 against it).
  Stage C  "surviving leaves go into priority queues ordered by lower
           bound; workers pop, stop a queue when its head's LB >= BSF"
                                                  -> the `query_major`
           schedule (per-query LB-argsorted blocks + lax.while_loop);
           `block_major` is the beyond-paper batched order (each block
           once, suffix-min stopping table — see EXPERIMENTS.md §Perf).
  k-NN BSF "the BSF array holds the k best-so-far answers; pruning uses
           the k-th best distance"                -> the shared top-k
           Frontier (core/frontier.py): pruning against
           ``frontier.threshold()`` can never discard a true k-NN
           member (no false dismissals, any k).
  per-series lower-bound filtering inside a leaf  -> ED(lb_filter=True)
           masks refinement to series whose own MINDIST < threshold
           (the stats expose the paper's "MESSI performs fewer real
           distance calculations" claim).
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from repro.core import engine
from repro.core.engine import ED, QueryPlan
from repro.core.frontier import Frontier, INF, SearchStats  # re-exported
from repro.core.index import BlockIndex


class SearchResult(NamedTuple):
    dist: jax.Array              # (Q, K) exact k-NN distances, ascending
    idx: jax.Array               # (Q, K) original ids; -1 = fewer than K real
    stats: SearchStats

    @property
    def nn_dist(self) -> jax.Array:
        """(Q,) nearest-neighbour distance (the k=1 column)."""
        return self.dist[..., 0]

    @property
    def nn_idx(self) -> jax.Array:
        """(Q,) nearest-neighbour id (the k=1 column)."""
        return self.idx[..., 0]


def refine_panel(q: jax.Array, q_paa: jax.Array, front: Frontier,
                 stats: SearchStats, block: jax.Array, ids_b: jax.Array,
                 lo: jax.Array | None, hi: jax.Array | None,
                 active: jax.Array, thr: jax.Array, *, n: int, w: int,
                 lb_filter: bool) -> tuple[Frontier, SearchStats]:
    """Back-compat shim: the ED specialization of ``engine.panel_refine``."""
    qs = engine.QueryState(q=q, aux=(q_paa,))
    return engine.panel_refine(ED(lb_filter=lb_filter), qs, front, stats,
                               block, ids_b, lo, hi, active, thr, n=n, w=w)


def search(index: BlockIndex, queries: jax.Array, *, k: int = 1,
           blocks_per_iter: int = 4, lb_filter: bool = True,
           initial_threshold: jax.Array | None = None,
           deadline_blocks: int | None = None,
           normalize_queries: bool = True) -> SearchResult:
    """Exact k-NN for a batch of queries (Q, n) against one index shard.

    ``initial_threshold`` tightens the pruning bound (squared distance) —
    the distributed path passes the globally-reduced k-th-best approximate
    distance here (paper's shared-BSF variable); it never appears in the
    result, which always holds this shard's own top-k.
    ``deadline_blocks`` caps refined blocks per query (straggler mitigation /
    anytime answers; None = exact).
    ``normalize_queries=False`` is the generic-vector path (core/vector.py):
    the index was built with normalize=False and queries arrive prepared.
    """
    plan = QueryPlan(metric=ED(normalize=normalize_queries,
                               lb_filter=lb_filter),
                     schedule="query_major", k=k,
                     blocks_per_iter=blocks_per_iter,
                     deadline_blocks=deadline_blocks)
    return engine.run(index, queries, plan, initial_threshold)


def search_block_major(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                       lb_filter: bool = True,
                       initial_threshold: jax.Array | None = None,
                       deadline_blocks: int | None = None,
                       normalize_queries: bool = True) -> SearchResult:
    """Exact k-NN with the BLOCK-major schedule (beyond-paper optimization).

    Blocks are visited ONCE each, in ascending min-over-queries lower-bound
    order; every visit is one contiguous ``dynamic_slice`` plus one (Q, C)
    MXU panel against all still-active queries, with the suffix-min table
    supplying the exact per-query stopping rule (measured rationale in
    EXPERIMENTS.md §Perf; schedule internals in core/engine.py).
    """
    plan = QueryPlan(metric=ED(normalize=normalize_queries,
                               lb_filter=lb_filter),
                     schedule="block_major", k=k,
                     deadline_blocks=deadline_blocks)
    return engine.run(index, queries, plan, initial_threshold)
