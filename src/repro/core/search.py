"""MESSI-style exact 1-NN query answering, vectorized for TPU (DESIGN.md §4).

Paper mapping:
  Stage A  "search the tree for the query's leaf, compute real distances in
           it, store the minimum in BSF"            -> best-envelope block
           argmin + one batched L2 against it.
  Stage C  "surviving leaves go into priority queues ordered by lower bound;
           workers pop, stop a queue when its head's LB >= BSF"
                                                    -> per-query LB-argsorted
           block schedule + lax.while_loop that refines the next K blocks per
           iteration and exits when every query's next block LB >= its BSF.
           Ordered traversal + that stopping rule ARE the priority-queue
           semantics; the heap itself is an artifact of MIMD threads.
  per-series lower-bound filtering inside a leaf     -> lb_filter=True masks
           refinement to series whose own MINDIST < BSF (the stats expose the
           paper's "MESSI performs fewer real distance calculations" claim).

Exactness (property-tested): LB <= true distance everywhere, so skipping only
blocks/series with LB >= BSF can never discard the nearest neighbor.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import isax
from repro.core.index import BlockIndex
from repro.kernels import ops

INF = jnp.float32(jnp.finfo(jnp.float32).max)


class SearchStats(NamedTuple):
    """Work counters, per query — the quantities behind the paper's Fig. 9/12."""
    blocks_visited: jax.Array    # envelopes that survived pruning & were refined
    series_refined: jax.Array    # real-distance computations performed
    lb_series: jax.Array         # per-series lower bounds computed
    iters: jax.Array             # while_loop trips (scalar, shared)


class SearchResult(NamedTuple):
    dist: jax.Array              # (Q,) exact NN Euclidean distance
    idx: jax.Array               # (Q,) original id of the NN
    stats: SearchStats


def _query_block_l2(q: jax.Array, blocks: jax.Array) -> jax.Array:
    """Per-query distances to its own gathered block(s).

    q (Q, n); blocks (Q, ..., C, n) -> (Q, ..., C) squared distances, using
    the same expanded form as the MXU kernel (einsum keeps it fused).
    """
    qq = jnp.sum(q * q, axis=-1)                              # (Q,)
    xx = jnp.sum(blocks * blocks, axis=-1)                    # (Q, ..., C)
    cross = jnp.einsum("qn,q...n->q...", q, blocks)
    extra = xx.ndim - 1
    qq = qq.reshape(qq.shape + (1,) * extra)
    return jnp.maximum(qq + xx - 2.0 * cross, 0.0)


def approximate_search(index: BlockIndex, q: jax.Array, q_paa: jax.Array
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stage A: initial BSF from each query's best-envelope block.

    Returns (bsf_sq (Q,), best_id (Q,), block_lb (Q, B))."""
    block_lb = ops.lb_scan_planar(q_paa, index.elo, index.ehi, n=index.n)
    b0 = jnp.argmin(block_lb, axis=1)                         # (Q,)
    blocks = index.raw[b0]                                    # (Q, C, n)
    d = _query_block_l2(q, blocks)                            # (Q, C)
    j = jnp.argmin(d, axis=1)
    bsf = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
    best = index.ids[b0, j]
    return bsf, best, block_lb


@functools.partial(jax.jit, static_argnames=("blocks_per_iter", "lb_filter",
                                             "deadline_blocks",
                                             "normalize_queries"))
def search(index: BlockIndex, queries: jax.Array, *,
           blocks_per_iter: int = 4, lb_filter: bool = True,
           initial_bsf: jax.Array | None = None,
           deadline_blocks: int | None = None,
           normalize_queries: bool = True) -> SearchResult:
    """Exact 1-NN for a batch of queries (Q, n) against one index shard.

    ``initial_bsf`` seeds the BSF (squared) — the distributed path passes the
    globally-reduced approximate BSF here (paper's shared-BSF variable).
    ``deadline_blocks`` caps refined blocks per query (straggler mitigation /
    anytime answers; None = exact).
    ``normalize_queries=False`` is the generic-vector path (core/vector.py):
    the index was built with normalize=False and queries arrive prepared.
    """
    q = (isax.znorm(queries) if normalize_queries else queries
         ).astype(jnp.float32)
    q_paa = isax.paa(q, index.w)
    b, c, n = index.raw.shape
    qn = q.shape[0]
    k = min(blocks_per_iter, b)

    bsf, best, block_lb = approximate_search(index, q, q_paa)
    if initial_bsf is not None:
        tighter = initial_bsf < bsf
        bsf = jnp.minimum(bsf, initial_bsf)
        best = jnp.where(tighter, -2, best)   # -2: NN lives in another shard

    order = jnp.argsort(block_lb, axis=1)                     # (Q, B)
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    stats0 = SearchStats(
        blocks_visited=jnp.zeros((qn,), jnp.int32),
        series_refined=jnp.zeros((qn,), jnp.int32),
        lb_series=jnp.zeros((qn,), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
    )

    def next_lb(ptr, bsf_):
        nxt = jax.lax.dynamic_slice_in_dim(order, ptr, 1, axis=1)   # (Q,1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]     # (Q,)

    def cond(state):
        ptr, bsf_, _, _ = state
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(next_lb(ptr, bsf_) < bsf_))

    def body(state):
        ptr, bsf_, best_, st = state
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, k, axis=1)  # (Q,K)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)           # (Q,K)
        active = lbs < bsf_[:, None]                                # (Q,K)

        def refine(carry):
            bsf_i, best_i, st_i = carry
            blocks = index.raw[idxs]                                # (Q,K,C,n)
            ids = index.ids[idxs]                                   # (Q,K,C)
            if lb_filter:
                lo = index.slo[idxs]                                # (Q,K,w,C)
                hi = index.shi[idxs]
                qe = q_paa[:, None, :, None]                        # (Q,1,w,1)
                dd = jnp.maximum(jnp.maximum(lo - qe, qe - hi), 0.0)
                s_lb = (n / index.w) * jnp.sum(dd * dd, axis=2)     # (Q,K,C)
                s_act = (s_lb < bsf_i[:, None, None]) & active[..., None]
            else:
                s_act = jnp.broadcast_to(active[..., None], ids.shape)
            d = _query_block_l2(q, blocks)                          # (Q,K,C)
            d = jnp.where(s_act & (ids >= 0), d, INF)
            flat = d.reshape(qn, -1)
            j = jnp.argmin(flat, axis=1)
            dmin = jnp.take_along_axis(flat, j[:, None], axis=1)[:, 0]
            cand_id = jnp.take_along_axis(ids.reshape(qn, -1), j[:, None],
                                          axis=1)[:, 0]
            better = dmin < bsf_i
            new_bsf = jnp.where(better, dmin, bsf_i)
            new_best = jnp.where(better, cand_id, best_i)
            st_n = SearchStats(
                blocks_visited=st_i.blocks_visited
                + jnp.sum(active, axis=1, dtype=jnp.int32),
                series_refined=st_i.series_refined
                + jnp.sum(s_act & (ids >= 0), axis=(1, 2), dtype=jnp.int32),
                lb_series=st_i.lb_series
                + (jnp.sum(active, axis=1, dtype=jnp.int32) * c
                   if lb_filter else st_i.lb_series * 0),
                iters=st_i.iters,
            )
            return new_bsf, new_best, st_n

        bsf_n, best_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (bsf_, best_, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + k, bsf_n, best_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, bsf, best, stats = jax.lax.while_loop(
        cond, body, (ptr0, bsf, best, stats0))
    return SearchResult(dist=jnp.sqrt(bsf), idx=best, stats=stats)


@functools.partial(jax.jit, static_argnames=("lb_filter", "deadline_blocks",
                                             "normalize_queries"))
def search_block_major(index: BlockIndex, queries: jax.Array, *,
                       lb_filter: bool = True,
                       initial_bsf: jax.Array | None = None,
                       deadline_blocks: int | None = None,
                       normalize_queries: bool = True) -> SearchResult:
    """Exact 1-NN with a BLOCK-major schedule (beyond-paper optimization).

    The paper's MESSI pops per-query priority queues — each thread gathers
    ITS query's next-best leaf.  For a BATCH of queries on matrix hardware
    that plan re-fetches (Q x K x C x n) raw bytes per round; the fetches,
    not the pruned distance math, dominate (measured 92 ms/query vs 11
    ms/query brute force at 50k x 256 on CPU — see EXPERIMENTS.md §Perf).

    Here the roles flip: blocks are visited ONCE each, in ascending
    min-over-queries lower-bound order; every visit is one contiguous
    ``dynamic_slice`` (no gather) plus one (Q, C) MXU panel against all
    still-active queries.  A suffix-min table over the scheduled LB matrix
    gives the exact per-query stopping rule (when suffix_min[ptr, q] >=
    bsf[q] nothing later can improve q; when that holds for all q we stop)
    — the same no-false-dismissal guarantee, O(B log B) schedule setup.
    """
    q = (isax.znorm(queries) if normalize_queries else queries
         ).astype(jnp.float32)
    q_paa = isax.paa(q, index.w)
    b, c, n = index.raw.shape
    qn = q.shape[0]

    bsf, best, block_lb = approximate_search(index, q, q_paa)
    if initial_bsf is not None:
        tighter = initial_bsf < bsf
        bsf = jnp.minimum(bsf, initial_bsf)
        best = jnp.where(tighter, -2, best)

    order = jnp.argsort(jnp.min(block_lb, axis=0))            # (B,)
    sched_lb = block_lb[:, order]                             # (Q, B)
    # suffix min over the schedule: can anything at >= ptr still help q?
    suffix = jax.lax.cummin(sched_lb[:, ::-1], axis=1)[:, ::-1]
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    stats0 = SearchStats(
        blocks_visited=jnp.zeros((qn,), jnp.int32),
        series_refined=jnp.zeros((qn,), jnp.int32),
        lb_series=jnp.zeros((qn,), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
    )

    def cond(state):
        ptr, bsf_, _, _ = state
        live = jax.lax.dynamic_slice_in_dim(suffix, ptr, 1, axis=1)[:, 0]
        return jnp.logical_and(ptr < max_ptr, jnp.any(live < bsf_))

    def body(state):
        ptr, bsf_, best_, st = state
        b_id = order[ptr]
        lbs = jax.lax.dynamic_slice_in_dim(block_lb, b_id, 1, axis=1)[:, 0]
        active = lbs < bsf_                                   # (Q,)

        def refine(cr):
            bsf_i, best_i, st_i = cr
            block = jax.lax.dynamic_index_in_dim(index.raw, b_id, 0,
                                                 keepdims=False)   # (C, n)
            ids_b = jax.lax.dynamic_index_in_dim(index.ids, b_id, 0,
                                                 keepdims=False)   # (C,)
            if lb_filter:
                lo = jax.lax.dynamic_index_in_dim(index.slo, b_id, 0,
                                                  keepdims=False)  # (w, C)
                hi = jax.lax.dynamic_index_in_dim(index.shi, b_id, 0,
                                                  keepdims=False)
                qe = q_paa[:, :, None]                             # (Q, w, 1)
                dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]),
                                 0.0)
                s_lb = (n / index.w) * jnp.sum(dd * dd, axis=1)    # (Q, C)
                s_act = (s_lb < bsf_i[:, None]) & active[:, None]
            else:
                s_act = jnp.broadcast_to(active[:, None], (qn, c))
            d = ops.batch_l2(q, block)                             # (Q, C)
            d = jnp.where(s_act & (ids_b >= 0)[None, :], d, INF)
            j = jnp.argmin(d, axis=1)
            dmin = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
            better = dmin < bsf_i
            st_n = SearchStats(
                blocks_visited=st_i.blocks_visited
                + active.astype(jnp.int32),
                series_refined=st_i.series_refined
                + jnp.sum(s_act & (ids_b >= 0)[None, :], axis=1,
                          dtype=jnp.int32),
                lb_series=st_i.lb_series
                + (active.astype(jnp.int32) * c if lb_filter
                   else st_i.lb_series * 0),
                iters=st_i.iters,
            )
            return (jnp.where(better, dmin, bsf_i),
                    jnp.where(better, ids_b[j], best_i), st_n)

        bsf_n, best_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (bsf_, best_, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + 1, bsf_n, best_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, bsf, best, stats = jax.lax.while_loop(
        cond, body, (ptr0, bsf, best, stats0))
    return SearchResult(dist=jnp.sqrt(bsf), idx=best, stats=stats)
