"""Block index construction — the TPU-native ParIS/MESSI index (DESIGN.md §4).

The pointer-based iSAX tree of the paper becomes a two-level flat structure:

  level 1: fixed-capacity *blocks* (= leaves), formed by sorting series by
           their bit-interleaved iSAX word (the breadth-first tree order) and
           cutting the sorted sequence every ``capacity`` series;
  level 2: per-block *envelopes* (= leaf iSAX summaries): segment-wise
           [min lo, max hi] over the member series' symbol regions.

Because the envelope contains every member's region, the envelope MINDIST is
<= every member's MINDIST <= the true distance: the no-false-dismissal
guarantee of the iSAX tree carries over unchanged (property-tested).

The raw series are physically permuted into block order so refinement reads
contiguous HBM, and the per-series bounds are stored planar (w on sublanes,
series on lanes) for the Pallas lower-bound kernel.

Everything here is jit-compatible so the distributed builder can run it
inside shard_map — that is the paper's "every worker builds its own subtrees
independently, no synchronization" property, obtained by construction.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isax
from repro.kernels import ops

RAW_PAD = 1.0e4   # pad-series point value: squared distance >> any real one


class HostRawBlocks:
    """Host-side raw blocks of an index opened out-of-core (DESIGN.md §5).

    Wraps the (B, C, n) raw section of a persisted index — normally an
    ``np.memmap`` over the index file — so the streaming search
    (storage/ooc_search.py) can fetch one block at a time while only the
    summaries/envelopes live on device.  Rides in the ``BlockIndex``
    treedef as static metadata, so it uses default identity hash/eq: the
    contents never reach a trace, only ``fetch`` results do, as operands.
    """

    def __init__(self, blocks, path: str | None = None):
        self.blocks = blocks
        self.path = path

    @property
    def dtype(self) -> np.dtype:
        """On-disk dtype of the raw series (I/O accounting derives
        itemsize from this, not from an assumed float32)."""
        return np.dtype(self.blocks.dtype)

    @property
    def n_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def block_nbytes(self) -> int:
        """Bytes of one (C, n) raw block as stored on disk."""
        _, c, n = self.blocks.shape
        return c * n * self.dtype.itemsize

    def fetch(self, block_id: int) -> np.ndarray:
        """Read one (C, n) block into a fresh host array (the disk I/O).

        Called from the block cache's background reader thread
        (storage/cache.py) as well as the driver: read-only memmap
        slicing plus a fresh-array copy, so concurrent calls are safe.
        """
        return np.ascontiguousarray(self.blocks[block_id])


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["raw", "slo", "shi", "elo", "ehi", "ids"],
    meta_fields=["n", "w", "card", "capacity", "n_real", "host_raw"],
)
@dataclasses.dataclass
class BlockIndex:
    """The in-memory index (one shard of it, in the distributed setting)."""
    raw: jax.Array   # (B, C, n) f32   z-normed series, block order, padded
    slo: jax.Array   # (B, w, C) f32   per-series region lower bounds
    shi: jax.Array   # (B, w, C) f32   per-series region upper bounds
    elo: jax.Array   # (w, B)  f32     block envelope lower bounds (planar)
    ehi: jax.Array   # (w, B)  f32     block envelope upper bounds (planar)
    ids: jax.Array   # (B, C) int32    original series ids (-1 = padding)
    n: int           # series length
    w: int
    card: int
    capacity: int
    n_real: int      # number of non-padding series
    # Out-of-core hook: set by storage.open_index, which leaves ``raw`` as a
    # zero-width (B, 0, n) placeholder and keeps the real blocks on disk.
    # The device search paths refuse such an index (engine/frontier prepare);
    # storage.ooc_search streams blocks through HostRawBlocks.fetch instead.
    host_raw: HostRawBlocks | None = None

    @property
    def n_blocks(self) -> int:
        return self.raw.shape[0]

    @property
    def device_resident(self) -> bool:
        """True when the raw series are on device (the in-memory paths)."""
        return self.raw.shape[1] == self.capacity


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["raw", "lo", "hi", "ids"],
    meta_fields=["n", "w", "card", "n_real"],
)
@dataclasses.dataclass
class FlatIndex:
    """ParIS view: the SAX-array scan needs no blocks, just planar bounds."""
    raw: jax.Array   # (Np, n) f32
    lo: jax.Array    # (w, Np) f32
    hi: jax.Array    # (w, Np) f32
    ids: jax.Array   # (Np,) int32
    n: int
    w: int
    card: int
    n_real: int


def block_layout(n_series: int, capacity: int) -> tuple[int, int, int]:
    """-> (cap, n_blocks, n_padded): the one definition of how N series cut
    into fixed-capacity blocks.  Shared by ``assemble_blocks`` and the
    out-of-core build pipeline (storage/pipeline/driver.py), so the two
    builders cannot disagree on padding and stay byte-compatible."""
    cap = min(capacity, n_series)
    n_padded = n_series + (-n_series) % cap
    return cap, n_padded // cap, n_padded


def build(raw: jax.Array, *, w: int = isax.W, card: int = isax.CARD,
          capacity: int = 512, normalize: bool = True,
          ids: jax.Array | None = None) -> BlockIndex:
    """Build the block index from raw series (N, n). Jit-compatible."""
    n_series, n = raw.shape
    if ids is None:
        ids = jnp.arange(n_series, dtype=jnp.int32)

    xn = isax.znorm(raw) if normalize else raw.astype(jnp.float32)
    _, sax = ops.summarize(xn, w=w, card=card, normalize=False)
    bounds = isax.bounds_from_sax(sax, card)                  # (N, w, 2)

    order = isax.sort_order(sax, w)
    return assemble_blocks(xn[order], bounds[order], ids[order],
                           n=n, w=w, card=card, capacity=capacity)


def block_envelopes(slo, shi, ids_b, xp=jnp):
    """Per-block envelopes from per-series bounds. -> (elo, ehi), (w, B).

    slo/shi (B, w, C), ids_b (B, C).  pad members are identified by id < 0,
    NOT by sentinel values: a REAL series in the top (or bottom) symbol
    region legitimately carries a +/-SENTINEL edge, and excluding it would
    shrink the envelope below a member's region — a false-dismissal bug
    (caught by the hypothesis envelope-containment property).  Blocks that
    are pure padding get a sentinel envelope (never selected).

    ``xp`` is the array namespace: jnp for the jit-compatible builders
    here, np for the out-of-core builder (storage/ooc_build.py) — one
    definition of the envelope rules for both.
    """
    real = (ids_b >= 0)[:, None, :]                           # (B, 1, C)
    elo = xp.min(xp.where(real, slo, isax.SENTINEL), axis=2).T     # (w, B)
    ehi = xp.max(xp.where(real, shi, -isax.SENTINEL), axis=2).T    # (w, B)
    any_real = xp.any(ids_b >= 0, axis=1)                     # (B,)
    elo = xp.where(any_real[None, :], elo, isax.SENTINEL)
    ehi = xp.where(any_real[None, :], ehi, isax.SENTINEL)
    return elo, ehi


def assemble_blocks(xn: jax.Array, bounds: jax.Array, ids: jax.Array, *,
                    n: int, w: int, card: int, capacity: int) -> BlockIndex:
    """Cut iSAX-sorted series into fixed-capacity blocks (+ envelopes).

    Inputs are already in sorted (tree) order; this is the IndexConstruction
    stage shared by the one-shot and the incremental (ParIS+) builders.
    """
    n_series = xn.shape[0]
    cap, b, n_padded = block_layout(n_series, capacity)
    pad = n_padded - n_series
    if pad:
        xn = jnp.concatenate(
            [xn, jnp.full((pad, n), RAW_PAD, jnp.float32)], axis=0)
        bounds = jnp.concatenate(
            [bounds, jnp.full((pad, w, 2), isax.SENTINEL, jnp.float32)], axis=0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], axis=0)

    raw_b = xn.reshape(b, cap, n)
    bounds_b = bounds.reshape(b, cap, w, 2)
    slo = jnp.transpose(bounds_b[..., 0], (0, 2, 1))          # (B, w, C)
    shi = jnp.transpose(bounds_b[..., 1], (0, 2, 1))
    elo, ehi = block_envelopes(slo, shi, ids.reshape(b, cap))

    return BlockIndex(raw=raw_b, slo=slo, shi=shi, elo=elo, ehi=ehi,
                      ids=ids.reshape(b, cap), n=n, w=w, card=card,
                      capacity=cap, n_real=n_series)


def flat_view(index: BlockIndex) -> FlatIndex:
    """Reinterpret the block index as a ParIS-style flat SAX array."""
    if not index.device_resident:
        raise ValueError("flat_view needs device-resident raw series; this "
                         "index was opened out-of-core (storage.open_index)")
    b, c, n = index.raw.shape
    w = index.w
    lo = jnp.transpose(index.slo, (1, 0, 2)).reshape(w, b * c)
    hi = jnp.transpose(index.shi, (1, 0, 2)).reshape(w, b * c)
    return FlatIndex(raw=index.raw.reshape(b * c, n), lo=lo, hi=hi,
                     ids=index.ids.reshape(b * c), n=index.n, w=w,
                     card=index.card, n_real=index.n_real)


def build_flat(raw: jax.Array, *, w: int = isax.W, card: int = isax.CARD,
               normalize: bool = True) -> FlatIndex:
    """Build only the ParIS flat SAX array (no sort, as in the paper)."""
    n_series, n = raw.shape
    xn = isax.znorm(raw) if normalize else raw.astype(jnp.float32)
    _, sax = ops.summarize(xn, w=w, card=card, normalize=False)
    bounds = isax.bounds_from_sax(sax, card)                  # (N, w, 2)
    return FlatIndex(raw=xn, lo=bounds[..., 0].T, hi=bounds[..., 1].T,
                     ids=jnp.arange(n_series, dtype=jnp.int32),
                     n=n, w=w, card=card, n_real=n_series)
