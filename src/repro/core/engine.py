"""One query engine: metric x schedule x backend (DESIGN.md §4).

ParIS/ParIS+ (on-disk) and MESSI (in-memory) are one algorithmic
skeleton — rank candidates by a lower bound, seed a best-so-far top-k,
refine survivors under the tightening k-th-best bound — specialized to
where the raw data lives and how workers coordinate.  This module is
that skeleton, written once, with each axis pluggable:

  * **metric** — what "distance" and "lower bound" mean.  A ``Metric``
    supplies query preparation, the block-envelope lower bound, the
    per-series lower bound and the exact distance; concrete adapters:
    ``ED`` (z-normed Euclidean, the paper's core), ``DTW(r)`` (Sakoe-
    Chiba band, the paper's §V extension over the UNCHANGED index) and
    ``Cosine`` (unit-norm embeddings, the paper's §V vector claim).
  * **schedule** — the traversal order and stopping rule.
    ``query_major`` (paper-faithful per-query priority order),
    ``block_major`` (each block once, min-over-queries order with a
    suffix-min stopping table) and ``flat`` (the ParIS whole-SAX-array
    scan with chunked refinement).
  * **backend** — where raw series live.  Device-resident indexes run
    fully jitted (``run`` / ``run_flat``); indexes opened out-of-core
    run the same block-major walk at the host level, every fetch and
    speculative prefetch driven through a callback into a
    ``storage.BlockCache`` (``run_cached``, used by
    ``storage.SearchSession``).

The public drivers (``core.search``, ``core.dtw``, ``core.vector``,
``core.paris``, ``storage.SearchSession``) are thin wrappers that
construct plans; the distributed two-round protocol
(``core.distributed``) wraps ANY plan, with round 1's work captured in
a resumable ``PreparedSearch`` (``prepare`` / ``run_cached_stage_a``)
that round 2 (``run`` / ``run_cached``) resumes instead of recomputing.  Every ``Metric.distances``
call lives in this module: the two pruned refine loops
(``panel_refine``, shared by both block-major backends, and the
gathered refine inside ``_query_major``) are where the DESIGN.md §8
fused LB+select kernel plugs in; stage-A seeding (``prepare`` /
``_cached_stage_a``) and the flat chunk refine (``run_flat``) also
call it and need the same swap to fuse end to end.

Exactness: a schedule only skips work whose metric lower bound is >= the
frontier's k-th-best distance, and every metric's bounds satisfy
``block_lb <= series_lb <= distance``, so no true k-NN member is ever
dismissed — for any metric, schedule, backend, or k.
"""
# repro: sync-trace — every device->host transfer in this module must
# carry a '# sync' (deliberate) or '# host' (host-data, no transfer)
# annotation; `python -m repro.analysis` enforces it (DESIGN.md §10)
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.frontier import Frontier, INF, SearchStats, query_block_l2
from repro.core.index import BlockIndex, FlatIndex, RAW_PAD
from repro.kernels import ops, ref

_bound = frontier_lib.bound

SCHEDULES = ("query_major", "block_major", "flat")


class QueryState(NamedTuple):
    """Metric-prepared queries: ``q`` plus metric-owned aux arrays
    (ED/Cosine: the PAA; DTW: the Keogh envelope and its PAA)."""
    q: jax.Array
    aux: tuple


# ---------------------------------------------------------------------------
# metric adapters
# ---------------------------------------------------------------------------

def prep_vectors(v: jax.Array, unit_norm: bool = True) -> jax.Array:
    """Embedding preparation for the Cosine metric (was core/vector.py).

    Unit-normalization makes Euclidean top-k == cosine top-k; the
    sqrt(d) rescale keeps per-dim values ~N(0,1)-sized so the iSAX
    breakpoints (standard-normal quantiles) stay discriminative.  A
    global scale preserves the NN ordering exactly.
    """
    v = v.astype(jnp.float32)
    if unit_norm:
        v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-8)
        v = v * jnp.sqrt(jnp.float32(v.shape[-1]))
    return v


def query_envelope(q: jax.Array, r: int) -> tuple[jax.Array, jax.Array]:
    """Keogh envelope: U_i = max(q[i-r:i+r+1]), L_i = min(...). q (..., n)."""
    n = q.shape[-1]
    pads = [(0, 0)] * (q.ndim - 1) + [(r, r)]
    qu = jnp.pad(q, pads, constant_values=-jnp.inf)
    ql = jnp.pad(q, pads, constant_values=jnp.inf)
    iu = jnp.arange(n)[:, None] + jnp.arange(2 * r + 1)[None, :]
    u = jnp.max(qu[..., iu], axis=-1)
    l = jnp.min(ql[..., iu], axis=-1)
    return u, l


def lb_keogh(q_env: tuple[jax.Array, jax.Array], x: jax.Array) -> jax.Array:
    """LB_Keogh(Q, x)^2 for raw candidates. u,l (Q, n); x (N, n) -> (Q, N)."""
    u, l = q_env
    above = jnp.maximum(x[None] - u[:, None], 0.0)
    below = jnp.maximum(l[:, None] - x[None], 0.0)
    d = above + below   # at most one of the two is nonzero per element
    return jnp.sum(d * d, axis=-1)


def interval_planar_lb(u_paa: jax.Array, l_paa: jax.Array, lo: jax.Array,
                       hi: jax.Array, *, n: int) -> jax.Array:
    """Squared MINDIST of interval [l_paa, u_paa] to regions [lo, hi].

    Per segment: max(0, lo - u, l - hi) — zero when they overlap —
    which lower-bounds LB_Keogh_PAA and hence DTW against any series in
    the region.  Implemented with the existing planar kernel by
    querying u against (lo, +S) and l against (-S, hi) and summing the
    pieces.  lo/hi (w, M): M may be blocks (envelopes) or individual
    series (the flat schedule).
    """
    big = isax.SENTINEL
    w, m = lo.shape
    above = ops.lb_scan_planar(u_paa, lo,
                               jnp.full((w, m), big, jnp.float32), n=n)
    below = ops.lb_scan_planar(l_paa, jnp.full((w, m), -big, jnp.float32),
                               hi, n=n)
    return above + below


def dtw_band(a: jax.Array, b: jax.Array, r: int) -> jax.Array:
    """Exact squared-DTW with band r. a (..., n) vs b (..., n), broadcast.

    The anti-diagonal DP now lives in ``kernels/ref.py`` (it is the
    oracle for the Pallas wavefront kernel); this stays the generic
    arbitrary-rank entry point.  Panel-shaped refine callers go through
    ``ops.dtw_panel``, which dispatches to the kernel by mode.
    """
    return ref.dtw_band_ref(a, b, r)


@dataclasses.dataclass(frozen=True)
class ED:
    """Z-normalized Euclidean distance — the paper's core metric.

    ``lb_filter`` toggles the per-series MINDIST filter inside a
    surviving block (the paper's "MESSI performs fewer real distance
    calculations" mechanism); ``normalize=False`` is the prepared-vector
    path (queries arrive already cast/scaled).
    """
    normalize: bool = True
    lb_filter: bool = True

    @property
    def filters(self) -> bool:
        return self.lb_filter

    # per-series filtering reads the stored iSAX region bounds
    needs_bounds = True

    def prep_queries(self, queries: jax.Array, *, w: int) -> QueryState:
        q = (isax.znorm(queries) if self.normalize
             else queries).astype(jnp.float32)
        return QueryState(q=q, aux=(isax.paa(q, w),))

    def block_lb(self, qs: QueryState, lo: jax.Array, hi: jax.Array, *,
                 n: int) -> jax.Array:
        """MINDIST of each query to planar (w, M) region bounds -> (Q, M).

        M may be blocks (envelopes) or individual series (the flat
        schedule) — the bound is the same formula either way.
        """
        return ops.lb_scan_planar(qs.aux[0], lo, hi, n=n)

    def series_lb(self, qs: QueryState, block: jax.Array, lo: jax.Array,
                  hi: jax.Array, *, n: int, w: int) -> jax.Array:
        q_paa = qs.aux[0]
        if lo.ndim == 2:                                   # panel (w, C)
            qe = q_paa[:, :, None]                         # (Q, w, 1)
            dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
            return (n / w) * jnp.sum(dd * dd, axis=1)      # (Q, C)
        qe = q_paa[:, None, :, None]                       # gathered (Q,1,w,1)
        dd = jnp.maximum(jnp.maximum(lo - qe, qe - hi), 0.0)
        return (n / w) * jnp.sum(dd * dd, axis=2)          # (Q, K, C)

    def distances(self, qs: QueryState, block: jax.Array) -> jax.Array:
        if block.ndim == 2:            # shared (C, n) panel: one MXU pass
            return ops.batch_l2(qs.q, block)
        return query_block_l2(qs.q, block)   # per-query gather (Q, ..., C, n)

    def panel_topk(self, qs: QueryState, block: jax.Array, ids_b: jax.Array,
                   lo, hi, active: jax.Array, thr: jax.Array, k: int, *,
                   n: int, w: int) -> tuple[jax.Array, jax.Array, jax.Array]:
        """LB-filter + distance + (dist, id)-lex top-k over one (C, n)
        panel -> (sel_d (Q, k), sel_id (Q, k), n_live (Q,)).

        With the MINDIST filter on, the whole pipeline is ONE fused
        kernel (``ops.fused_panel_topk``); the per-query ``active`` mask
        folds into the threshold as -inf (``lb < -inf`` is never true)."""
        if self.lb_filter:
            return ops.fused_panel_topk(
                qs.q, qs.aux[0], block, lo, hi, ids_b,
                jnp.where(active, thr, -jnp.inf), k=k, n=n)
        live = active[:, None] & (ids_b >= 0)[None, :]
        d = jnp.where(live, self.distances(qs, block), INF)
        sd, si = ops.block_topk(d, jnp.where(live, ids_b[None, :], -1), k)
        return sd, si, jnp.sum(live, axis=1, dtype=jnp.int32)

    def finalize_stats(self, stats: SearchStats, capacity: int
                       ) -> SearchStats:
        """Counter semantics are already right for ED: ``series_refined``
        counts filter survivors (the panel is masked before insert)."""
        return stats


@dataclasses.dataclass(frozen=True)
class Cosine(ED):
    """Cosine similarity over embeddings, served as Euclidean top-k.

    ``prep_vectors`` maps both corpus (at build) and queries (here) onto
    the sqrt(d)-scaled unit sphere, where d^2 = dim * (2 - 2 cos) is
    monotone in cosine — so the exact ED frontier IS the exact cosine
    top-k, descending (``vector.cosine_scores`` inverts the map).
    """
    normalize: bool = False     # never z-norm embeddings
    unit_norm: bool = True

    def prep_queries(self, queries: jax.Array, *, w: int) -> QueryState:
        q = prep_vectors(queries, self.unit_norm)
        return QueryState(q=q, aux=(isax.paa(q, w),))


@dataclasses.dataclass(frozen=True)
class DTW:
    """Sakoe-Chiba-band DTW over the UNCHANGED Euclidean index (paper §V).

    The block lower bound widens the query to its Keogh envelope and
    takes the interval-to-region MINDIST, which lower-bounds
    LB_Keogh_PAA and hence DTW — no-false-dismissal carries over.  The
    per-series filter is LB_Keogh on the raw values (tighter than PAA);
    it reads the fetched block itself, so it needs no stored bounds.
    """
    r: int

    filters = True
    needs_bounds = False

    def prep_queries(self, queries: jax.Array, *, w: int) -> QueryState:
        q = isax.znorm(queries).astype(jnp.float32)
        u, l = query_envelope(q, self.r)
        return QueryState(q=q, aux=(u, l, isax.paa(u, w), isax.paa(l, w)))

    def block_lb(self, qs: QueryState, lo: jax.Array, hi: jax.Array, *,
                 n: int) -> jax.Array:
        """Interval [l_paa, u_paa] to region [lo, hi] MINDIST -> (Q, M)."""
        return interval_planar_lb(qs.aux[2], qs.aux[3], lo, hi, n=n)

    def series_lb(self, qs: QueryState, block: jax.Array, lo, hi, *,
                  n: int, w: int) -> jax.Array:
        u, l = qs.aux[0], qs.aux[1]
        if block.ndim == 2:                               # panel (C, n)
            return lb_keogh((u, l), block)                # (Q, C)
        above = jnp.maximum(block - u[:, None, None, :], 0.0)
        below = jnp.maximum(l[:, None, None, :] - block, 0.0)
        dd = above + below
        return jnp.sum(dd * dd, axis=-1)                  # (Q, K, C)

    def distances(self, qs: QueryState, block: jax.Array) -> jax.Array:
        if block.ndim <= 3:            # (C, n) panel or (Q, C, n) stage A
            return ops.dtw_panel(qs.q, block, r=self.r)
        qn, kb, c, n = block.shape                              # (Q,K,C,n)
        return ops.dtw_panel(qs.q, block.reshape(qn, kb * c, n),
                             r=self.r).reshape(qn, kb, c)

    def panel_topk(self, qs: QueryState, block: jax.Array, ids_b: jax.Array,
                   lo, hi, active: jax.Array, thr: jax.Array, k: int, *,
                   n: int, w: int) -> tuple[jax.Array, jax.Array, jax.Array]:
        """LB_Keogh filter + banded-DP panel + top-k select.  The filter
        reads the raw block (no stored bounds), so the LB stays a
        separate pass; the select is still the block_topk kernel."""
        s_lb = self.series_lb(qs, block, lo, hi, n=n, w=w)      # (Q, C)
        live = (s_lb < thr[:, None]) & active[:, None] & (ids_b >= 0)[None, :]
        d = jnp.where(live, self.distances(qs, block), INF)
        sd, si = ops.block_topk(d, jnp.where(live, ids_b[None, :], -1), k)
        return sd, si, jnp.sum(live, axis=1, dtype=jnp.int32)

    def finalize_stats(self, stats: SearchStats, capacity: int
                       ) -> SearchStats:
        """DTW's historical convention, now uniform across backends:
        every visited block costs a full panel of LB_Keogh bounds AND a
        full panel of banded-DP distances (the DP runs for all
        candidates, then masks), so ``series_refined == lb_series ==
        blocks_visited * capacity`` — the filter-survivor count the
        generic refine accumulated would claim pruning savings the DP
        never realizes."""
        v = stats.blocks_visited
        return SearchStats(blocks_visited=v, series_refined=v * capacity,
                           lb_series=v * capacity,
                           iters=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# prepared round-1 state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedSearch:
    """Round-1 state as a first-class resumable object (DESIGN.md §6).

    Everything the paper's approximate phase produces — metric-prepared
    queries, the block lower-bound matrix, the stage-A-seeded frontier,
    and the work stats accrued so far — plus, on the cached backend, the
    ids of the blocks stage A already fetched and refined.  Produced by
    ``prepare`` (device) / ``run_cached_stage_a`` (cached); accepted by
    ``run`` / ``run_cached`` so the two-round distributed protocol's
    second round skips query prep, block ranking, and every
    already-refined block instead of recomputing round 1.

    The frontier is a strictly-tighter seed, not a different answer:
    resuming from it is bit-identical to re-running round 1 under the
    seeded bound (candidates the global bound would have masked all have
    ``lb >= threshold`` and so can never displace a reported slot).

    Registered as a pytree with ``refined`` static, so it threads
    through jitted device code (``run`` donates it — round 2 reuses the
    round-1 frontier buffers instead of holding both alive).
    """
    qs: QueryState
    front: Frontier
    block_lb: jax.Array            # (Q, B) metric block lower bounds
    stats: SearchStats             # work already accrued (stage A)
    refined: frozenset = frozenset()   # block ids stage A refined (cached)

    @property
    def k(self) -> int:
        return self.front.k


jax.tree_util.register_dataclass(
    PreparedSearch,
    data_fields=("qs", "front", "block_lb", "stats"),
    meta_fields=("refined",))


def _check_prepared(prepared: PreparedSearch, plan: QueryPlan,
                    n_blocks: int, qn: int) -> None:
    if prepared.k != plan.k:
        raise ValueError(f"prepared state holds a k={prepared.k} frontier "
                         f"but the plan asks k={plan.k}; round 2 must reuse "
                         "the round-1 plan")
    if prepared.block_lb.shape[-1] != n_blocks:
        raise ValueError(
            f"prepared block_lb ranks {prepared.block_lb.shape[-1]} blocks "
            f"but this index has {n_blocks}; the prepared state belongs to "
            "a different index")
    if prepared.block_lb.shape[0] != qn:
        raise ValueError(
            f"prepared state was built for {prepared.block_lb.shape[0]} "
            f"queries but {qn} were passed; round 2 must reuse the round-1 "
            "query batch (only the shape is checkable here — binding the "
            "CONTENT is the caller's job, as storage.SearchSession does "
            "via its query fingerprint)")


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One cell of the metric x schedule matrix, plus its tuning knobs.

    Hashable (static under jit): the pruning-threshold seed — a traced
    (Q,) array in the distributed protocol — is an argument of
    ``run``/``run_flat``/``run_cached``, never part of the plan.  The
    backend axis is picked by which runner the plan is handed to.
    """
    metric: object = ED()
    schedule: str = "block_major"
    k: int = 1
    blocks_per_iter: int = 4        # query_major refine width
    deadline_blocks: int | None = None   # anytime cap; None = exact
    chunk: int = 4096               # flat-schedule refinement chunk

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, "
                             f"got {self.schedule!r}")
        if self.deadline_blocks is not None and self.deadline_blocks < 1:
            # fail at plan construction, not deep inside a walk: every
            # backend clamps the deadline against n_blocks, and a <= 0
            # deadline would silently clamp to an empty walk — an
            # approximate answer the caller never asked for
            raise ValueError(
                f"deadline_blocks must be >= 1 (or None for an exact "
                f"search), got {self.deadline_blocks}")


def _require_device_resident(index: BlockIndex) -> None:
    if not index.device_resident:
        raise ValueError(
            "index raw series are not device-resident (opened out-of-core "
            "via storage.open_index); use engine.run_cached through a "
            "storage.SearchSession (or storage.ooc_search), or "
            "storage.load_index for the in-memory backends")


def prepare(metric, index: BlockIndex, queries: jax.Array, k: int
            ) -> PreparedSearch:
    """Metric prep + block ranking + stage-A seeding (device backend).

    The paper's approximate phase, metric-generic: one block-LB kernel
    pass ranks every envelope, then each query's best block is refined
    exactly and seeds the top-k frontier.  Returns a ``PreparedSearch``
    the distributed protocol threads into ``run`` as round-2 state
    (``refined`` stays empty: the device walk keeps revisiting stage-A
    blocks — a resident panel costs no I/O, and the frontier insert
    dedups by id — so skipping them would change last-ulp min-of-both
    distances and break bit-stability with the non-protocol paths).
    """
    _require_device_resident(index)
    qs = metric.prep_queries(queries, w=index.w)
    qn = qs.q.shape[0]
    block_lb = metric.block_lb(qs, index.elo, index.ehi, n=index.n)
    b0 = jnp.argmin(block_lb, axis=1)                         # (Q,)
    ids0 = index.ids[b0]                                      # (Q, C)
    d0 = metric.distances(qs, index.raw[b0])                  # (Q, C)
    # pad lanes (id < 0) hold RAW_PAD series with FINITE huge distances —
    # mask to INF before the select (block_topk's masking contract)
    sd, si = ops.block_topk(jnp.where(ids0 >= 0, d0, INF), ids0, k)
    front = frontier_lib.init(qn, k).insert_topk(sd, si)
    return PreparedSearch(qs=qs, front=front, block_lb=block_lb,
                          stats=frontier_lib.stats_init(qn))


def panel_refine(metric, qs: QueryState, front: Frontier, stats: SearchStats,
                 block: jax.Array, ids_b: jax.Array,
                 lo: jax.Array | None, hi: jax.Array | None,
                 active: jax.Array, thr: jax.Array, *,
                 n: int, w: int) -> tuple[Frontier, SearchStats]:
    """Refine one (C, n) raw block panel against every query at once.

    The per-block unit of work shared by the block-major schedule on
    both backends (device while_loop and the cached host walk): the
    metric's ``panel_topk`` pipeline — per-series lower-bound filtering,
    distances, and the (dist, id)-lex top-k select, fused into one
    kernel where the metric allows — then an ``insert_topk`` merge
    (2k-wide, not K + C) and the work-stat updates.  ``active`` (Q,)
    masks queries whose block lower bound beat ``thr``; ``lo``/``hi``
    are the block's (w, C) per-series bounds (None when the metric
    filters off the raw values, or not at all).
    """
    c = block.shape[0]
    sd, si, nlive = metric.panel_topk(qs, block, ids_b, lo, hi, active,
                                      thr, front.k, n=n, w=w)
    front = front.insert_topk(sd, si)
    stats = SearchStats(
        blocks_visited=stats.blocks_visited + active.astype(jnp.int32),
        series_refined=stats.series_refined + nlive,
        lb_series=stats.lb_series
        + (active.astype(jnp.int32) * c if metric.filters else 0),
        iters=stats.iters,
    )
    return front, stats


# ---------------------------------------------------------------------------
# device backend: the two ordered schedules + the flat scan
# ---------------------------------------------------------------------------

def _query_major(metric, index: BlockIndex, qs: QueryState, front: Frontier,
                 block_lb: jax.Array, stats0: SearchStats, *,
                 blocks_per_iter: int, deadline_blocks: int | None,
                 initial_threshold) -> tuple[Frontier, SearchStats]:
    """Paper-faithful order: each query refines ITS next-best blocks.

    Per-query LB-argsorted schedule + lax.while_loop refining the next
    ``blocks_per_iter`` blocks per trip; exits when every query's next
    block LB >= its pruning bound.  Ordered traversal + that stopping
    rule ARE the paper's priority-queue semantics; the heap itself is an
    artifact of MIMD threads.
    """
    b, c, n = index.raw.shape
    qn = qs.q.shape[0]
    kb = min(blocks_per_iter, b)

    order = jnp.argsort(block_lb, axis=1)                     # (Q, B)
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def next_lb(ptr):
        # Invariant: ``cond`` evaluates this even when ptr >= max_ptr —
        # jnp.logical_and does not short-circuit — so after the final body
        # trip ptr can reach up to b + kb - 1.  The clamp keeps the slice
        # start in-bounds explicitly (the clamped value is discarded:
        # ptr < max_ptr is already False) instead of leaning on
        # dynamic_slice's implicit start clamping.
        safe = jnp.minimum(ptr, b - 1)
        nxt = jax.lax.dynamic_slice_in_dim(order, safe, 1, axis=1)  # (Q,1)
        return jnp.take_along_axis(block_lb, nxt, axis=1)[:, 0]     # (Q,)

    def cond(state):
        ptr, f, _ = state
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(next_lb(ptr)
                                       < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        idxs = jax.lax.dynamic_slice_in_dim(order, ptr, kb, axis=1)  # (Q,K)
        lbs = jnp.take_along_axis(block_lb, idxs, axis=1)            # (Q,K)
        active = lbs < thr[:, None]                                  # (Q,K)

        def refine(carry):
            f_i, st_i = carry
            blocks = index.raw[idxs]                                # (Q,K,C,n)
            ids = index.ids[idxs]                                   # (Q,K,C)
            if metric.filters:
                lo = index.slo[idxs] if metric.needs_bounds else None
                hi = index.shi[idxs] if metric.needs_bounds else None
                s_lb = metric.series_lb(qs, blocks, lo, hi,
                                        n=n, w=index.w)             # (Q,K,C)
                s_act = (s_lb < thr[:, None, None]) & active[..., None]
            else:
                s_act = jnp.broadcast_to(active[..., None], ids.shape)
            d = metric.distances(qs, blocks)                        # (Q,K,C)
            live = s_act & (ids >= 0)
            # blocks partition the series and idxs rows are distinct, so
            # ids are unique per row: block_topk's subset-exactness holds
            sd, si = ops.block_topk(
                jnp.where(live, d, INF).reshape(qn, -1),
                jnp.where(live, ids, -1).reshape(qn, -1), f_i.k)
            f_n = f_i.insert_topk(sd, si)
            st_n = SearchStats(
                blocks_visited=st_i.blocks_visited
                + jnp.sum(active, axis=1, dtype=jnp.int32),
                series_refined=st_i.series_refined
                + jnp.sum(live, axis=(1, 2), dtype=jnp.int32),
                lb_series=st_i.lb_series
                + (jnp.sum(active, axis=1, dtype=jnp.int32) * c
                   if metric.filters else 0),
                iters=st_i.iters,
            )
            return f_n, st_n

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + kb, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return front, stats


def block_major_schedule(block_lb, xp=jnp):
    """Shared block-major schedule: visit order + suffix-min stop table.

    Blocks ascend by min-over-queries lower bound; the suffix min over
    the scheduled LB matrix gives the exact stopping rule (when
    suffix[ptr, q] >= threshold[q] nothing later can improve q's top-k).
    ``xp`` is jnp on the device backend, np on the cached host walk —
    one definition of the schedule for both.
    """
    if xp is jnp:
        order = xp.argsort(xp.min(block_lb, axis=0))          # (B,)
        sched_lb = block_lb[:, order]                         # (Q, B)
        suffix = jax.lax.cummin(sched_lb[:, ::-1], axis=1)[:, ::-1]
    else:
        order = np.argsort(block_lb.min(axis=0), kind="stable")
        sched_lb = block_lb[:, order]
        suffix = np.minimum.accumulate(sched_lb[:, ::-1], axis=1)[:, ::-1]
    return order, sched_lb, suffix


def _block_major(metric, index: BlockIndex, qs: QueryState, front: Frontier,
                 block_lb: jax.Array, stats0: SearchStats, *,
                 deadline_blocks: int | None, initial_threshold
                 ) -> tuple[Frontier, SearchStats]:
    """Beyond-paper batched order: every block visited at most once.

    Each visit is one contiguous ``dynamic_slice`` (no gather) plus one
    (Q, C) panel against all still-active queries; the suffix-min table
    supplies the same no-false-dismissal stopping rule (see EXPERIMENTS.md
    §Perf for why this wins on batch hardware).
    """
    b, c, n = index.raw.shape

    order, _, suffix = block_major_schedule(block_lb)
    max_ptr = b if deadline_blocks is None else min(b, deadline_blocks)

    def cond(state):
        ptr, f, _ = state
        # same invariant as ``next_lb`` in the query-major schedule:
        # logical_and does not short-circuit, so this slice is evaluated
        # at ptr == max_ptr after the final trip — clamp explicitly (the
        # value is discarded)
        safe = jnp.minimum(ptr, b - 1)
        live = jax.lax.dynamic_slice_in_dim(suffix, safe, 1, axis=1)[:, 0]
        return jnp.logical_and(ptr < max_ptr,
                               jnp.any(live < _bound(f, initial_threshold)))

    def body(state):
        ptr, f, st = state
        thr = _bound(f, initial_threshold)
        b_id = order[ptr]
        lbs = jax.lax.dynamic_slice_in_dim(block_lb, b_id, 1, axis=1)[:, 0]
        active = lbs < thr                                    # (Q,)

        def refine(cr):
            f_i, st_i = cr
            block = jax.lax.dynamic_index_in_dim(index.raw, b_id, 0,
                                                 keepdims=False)   # (C, n)
            ids_b = jax.lax.dynamic_index_in_dim(index.ids, b_id, 0,
                                                 keepdims=False)   # (C,)
            lo = hi = None
            if metric.filters and metric.needs_bounds:
                lo = jax.lax.dynamic_index_in_dim(index.slo, b_id, 0,
                                                  keepdims=False)  # (w, C)
                hi = jax.lax.dynamic_index_in_dim(index.shi, b_id, 0,
                                                  keepdims=False)
            return panel_refine(metric, qs, f_i, st_i, block, ids_b, lo, hi,
                                active, thr, n=n, w=index.w)

        f_n, st_n = jax.lax.cond(
            jnp.any(active), refine, lambda cr: cr, (f, st))
        st_n = st_n._replace(iters=st_n.iters + 1)
        return ptr + 1, f_n, st_n

    ptr0 = jnp.zeros((), jnp.int32)
    _, front, stats = jax.lax.while_loop(cond, body, (ptr0, front, stats0))
    return front, stats


@functools.partial(jax.jit, static_argnames=("plan",),
                   donate_argnames=("prepared",))
def run(index: BlockIndex, queries: jax.Array, plan: QueryPlan,
        initial_threshold: jax.Array | None = None,
        prepared: PreparedSearch | None = None):
    """Execute a plan against a device-resident index. -> SearchResult.

    ``initial_threshold`` tightens the pruning bound (squared distance)
    — the distributed protocol passes the globally-reduced k-th-best
    here (the paper's shared-BSF variable); it never appears in the
    result, which always holds this index's own top-k.

    ``prepared`` resumes from a round-1 ``PreparedSearch`` (same metric,
    index, queries, and k — ``prepare`` produces it) instead of paying
    for query prep, block ranking, and stage A again; it is donated, so
    the caller must treat it as consumed.
    """
    from repro.core.search import SearchResult   # thin wrapper layer
    if plan.schedule == "flat":
        raise ValueError("the flat schedule scans a FlatIndex — use "
                         "engine.run_flat (or paris.search_flat)")
    if prepared is None:
        prepared = prepare(plan.metric, index, queries, plan.k)
    else:
        _check_prepared(prepared, plan, index.n_blocks, queries.shape[0])
    qs, front, block_lb, stats0 = (prepared.qs, prepared.front,
                                   prepared.block_lb, prepared.stats)
    if plan.schedule == "query_major":
        front, stats = _query_major(
            plan.metric, index, qs, front, block_lb, stats0,
            blocks_per_iter=plan.blocks_per_iter,
            deadline_blocks=plan.deadline_blocks,
            initial_threshold=initial_threshold)
    else:
        front, stats = _block_major(
            plan.metric, index, qs, front, block_lb, stats0,
            deadline_blocks=plan.deadline_blocks,
            initial_threshold=initial_threshold)
    stats = plan.metric.finalize_stats(stats, index.capacity)
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


@functools.partial(jax.jit, static_argnames=("plan",))
def run_flat(index: FlatIndex, queries: jax.Array, plan: QueryPlan,
             block_index: BlockIndex | None = None,
             initial_threshold: jax.Array | None = None):
    """The ParIS schedule: one planar LB pass over EVERY series, then
    chunked candidate refinement with the running frontier.

    ``block_index`` (optional) enables stage-A seeding from the block
    view; without it the scan starts from an empty frontier (the first
    chunk is then refined in full, which seeds it).  Metric-generic: the
    per-series planar bound is the same ``Metric.block_lb`` formula
    evaluated on per-series (not per-block) region bounds.

    ``plan.deadline_blocks`` (anytime, in CHUNK units — the flat
    schedule's block analogue) caps the number of chunks refined: the LB
    pass still covers every series, but once the cap is hit later
    chunks' candidates are skipped, exactly like a deadline-cut
    block-major walk defers its unvisited blocks.
    """
    from repro.core.search import SearchResult
    metric = plan.metric
    npad, n = index.raw.shape
    if block_index is not None:
        prep = prepare(metric, block_index, queries, plan.k)
        qs, front = prep.qs, prep.front
    else:
        qs = metric.prep_queries(queries, w=index.w)
        front = frontier_lib.init(qs.q.shape[0], plan.k)
    q = qs.q
    qn = q.shape[0]
    c = min(plan.chunk, npad)
    pad = (-npad) % c

    lo, hi, raw, ids = index.lo, index.hi, index.raw, index.ids
    if pad:
        lo = jnp.concatenate([lo, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        hi = jnp.concatenate([hi, jnp.full((index.w, pad), isax.SENTINEL)], 1)
        raw = jnp.concatenate(
            [raw, jnp.full((pad, n), RAW_PAD, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], 0)

    # phase 2 — the flat LB scan over the ENTIRE SAX array (one kernel pass)
    lb = metric.block_lb(qs, lo, hi, n=n)                     # (Q, Np+pad)

    # phase 3 — chunked candidate refinement with the running frontier
    nchunks = raw.shape[0] // c
    raw_c = raw.reshape(nchunks, c, n)
    ids_c = ids.reshape(nchunks, c)
    lb_c = lb.reshape(qn, nchunks, c)

    deadline = plan.deadline_blocks      # static: None leaves the exact
                                         # scan's traced graph unchanged

    def step(carry, inp):
        front, refined, nref = carry
        raw_k, ids_k, lb_k = inp                              # (C,n),(C,),(Q,C)
        thr = _bound(front, initial_threshold)
        act = (lb_k < thr[:, None]) & (ids_k[None, :] >= 0)
        do = jnp.any(act)
        if deadline is not None:
            do = jnp.logical_and(do, nref < deadline)

        def refine(cr):
            front_j, refined_j = cr
            d = jnp.where(act, metric.distances(qs, raw_k), INF)  # (Q, C)
            sd, si = ops.block_topk(d, jnp.where(act, ids_k[None, :], -1),
                                    front_j.k)
            front_n = front_j.insert_topk(sd, si)
            return (front_n,
                    refined_j + jnp.sum(act, axis=1, dtype=jnp.int32))

        front, refined = jax.lax.cond(do, refine, lambda cr: cr,
                                      (front, refined))
        return (front, refined, nref + do.astype(jnp.int32)), None

    (front, refined, _), _ = jax.lax.scan(
        step, (front, jnp.zeros((qn,), jnp.int32), jnp.zeros((), jnp.int32)),
        (raw_c, ids_c, jnp.moveaxis(lb_c, 1, 0)))

    stats = SearchStats(
        blocks_visited=jnp.full((qn,), nchunks, jnp.int32),
        series_refined=refined,
        lb_series=jnp.full((qn,), index.n_real, jnp.int32),   # whole array
        iters=jnp.asarray(nchunks, jnp.int32),
    )
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


# ---------------------------------------------------------------------------
# cached backend: the same block-major walk, host-driven through callbacks
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "n", "w"))
def _cached_refine_step(metric, qs, front, stats, block, ids_b, lo, hi, lbs,
                        initial_threshold, *, n: int, w: int):
    """One fetched block against all queries — the device side of the walk."""
    thr = _bound(front, initial_threshold)
    active = lbs < thr
    return panel_refine(metric, qs, front, stats, block, ids_b, lo, hi,
                        active, thr, n=n, w=w)


@functools.partial(jax.jit, static_argnames=("metric", "n", "w"))
def _cached_refine_group(metric, qs, front, stats, blocks, ids_g, lo_g, hi_g,
                         lbs_g, initial_threshold, *, n: int, w: int):
    """G stacked blocks against all queries in ONE dispatch.

    ``blocks`` is the (G, C, n) stack of a group of consecutive surviving
    schedule slots; a ``lax.scan`` runs the same per-block body as
    ``_cached_refine_step`` over the group with the frontier as carry, so
    every block's active mask is computed against the threshold AFTER all
    earlier blocks in the group — exactly the threshold the serial walk
    would have shown it.  The host only picked the group under a stale
    (one-group-old) threshold; staleness can admit a block whose queries
    are all dead by its turn, and such a block contributes nothing: its
    active mask is all-False, so the frontier insert and every stat
    counter are no-ops.  Hence dist/idx AND stats are bit-identical to
    dispatching the group one block at a time.

    ``lo_g``/``hi_g`` are (G, w, C) stacked per-series bounds or None
    (metrics that filter off raw values, or not at all) — the None case
    traces a separate program, mirroring the single-block step.  One
    compile per distinct group length; partial final groups reuse the
    single-block step when they shrink to one block.
    """
    def body(carry, xs):
        f, st = carry
        if lo_g is None:
            block, ids_b, lbs = xs
            lo = hi = None
        else:
            block, ids_b, lo, hi, lbs = xs
        thr = _bound(f, initial_threshold)
        active = lbs < thr
        return panel_refine(metric, qs, f, st, block, ids_b, lo, hi,
                            active, thr, n=n, w=w), None
    xs = ((blocks, ids_g, lbs_g) if lo_g is None
          else (blocks, ids_g, lo_g, hi_g, lbs_g))
    (front, stats), _ = jax.lax.scan(body, (front, stats), xs)
    return front, stats


def cached_setup(index: BlockIndex, queries: jax.Array, plan: QueryPlan
                 ) -> PreparedSearch:
    """Query prep + block ranking for an index whose raw lives off-device.

    Only summaries/envelopes are touched (they are device-resident on an
    opened index); the frontier starts EMPTY — stage A needs raw blocks,
    which the walk fetches through its callback.
    """
    metric = plan.metric
    qs = metric.prep_queries(queries, w=index.w)
    qn = qs.q.shape[0]
    block_lb = metric.block_lb(qs, index.elo, index.ehi, n=index.n)
    return PreparedSearch(qs=qs, front=frontier_lib.init(qn, plan.k),
                          block_lb=block_lb,
                          stats=frontier_lib.stats_init(qn))


def _check_pipeline_knobs(pipeline_depth: int, group_blocks: int) -> None:
    if pipeline_depth < 1 or group_blocks < 1:
        raise ValueError(
            f"pipeline_depth and group_blocks must be >= 1 (1, 1 is the "
            f"serial walk), got ({pipeline_depth}, {group_blocks})")


class _GroupDispatcher:
    """Host side of the pipelined refine: stack a group, dispatch once.

    Shared by stage A and the walk.  A one-block group goes through
    ``_cached_refine_step`` — byte-for-byte today's serial dispatch, so
    (D=1, G=1) walks reuse the existing jit cache and stay bit-identical
    including stats; larger groups stack to (G, C, n) and run the
    ``lax.scan`` group kernel in a single dispatch (one host->device
    round trip, one threshold sync for the whole group).
    """

    def __init__(self, index: BlockIndex, plan: QueryPlan, block_lb,
                 fetch, initial_threshold):
        self.index = index
        self.metric = plan.metric
        self.block_lb = block_lb                 # (Q, B) device
        self.fetch = fetch
        self.thr0 = initial_threshold
        self.needs = plan.metric.filters and plan.metric.needs_bounds
        self.dispatches = 0

    def __call__(self, qs, front, stats, gids: list[int]):
        index, needs = self.index, self.needs
        self.dispatches += 1
        if len(gids) == 1:
            b = gids[0]
            lo = index.slo[b] if needs else None
            hi = index.shi[b] if needs else None
            return _cached_refine_step(
                self.metric, qs, front, stats, self.fetch(b), index.ids[b],
                lo, hi, self.block_lb[:, b], self.thr0,
                n=index.n, w=index.w)
        blocks = jnp.stack([self.fetch(b) for b in gids])        # (G, C, n)
        gi = jnp.asarray(np.asarray(gids, dtype=np.int32))       # host ids
        lo_g = index.slo[gi] if needs else None                  # (G, w, C)
        hi_g = index.shi[gi] if needs else None
        return _cached_refine_group(
            self.metric, qs, front, stats, blocks, index.ids[gi],
            lo_g, hi_g, jnp.transpose(self.block_lb[:, gi]),     # (G, Q)
            self.thr0, n=index.n, w=index.w)


def _cached_stage_a(index, plan, prep: PreparedSearch, block_lb_h,
                    fetch, speculate, initial_threshold, *,
                    pipeline_depth: int = 1, group_blocks: int = 1,
                    telemetry: dict | None = None) -> PreparedSearch:
    """Stage A on the cached backend: each query's best-envelope block
    seeds the frontier — a pure fetch/refine chain, so it gets the full
    pipeline treatment: the next ``pipeline_depth`` blocks are always in
    flight behind the reader pool, and up to ``group_blocks`` blocks ride
    one batched dispatch.  Returns the state with the refined block ids
    recorded, so a resumed walk never fetches or refines them again."""
    qs, front, stats = prep.qs, prep.front, prep.stats
    dispatch = _GroupDispatcher(index, plan, prep.block_lb, fetch,
                                initial_threshold)
    stage_a = [int(b) for b in np.unique(np.argmin(block_lb_h, axis=1))]
    i = 0
    while i < len(stage_a):
        gids = stage_a[i:i + group_blocks]
        for b in gids:                     # group reads first, in order
            speculate(b)
        nxt = i + len(gids)
        for b in stage_a[nxt:nxt + pipeline_depth]:    # depth-D lookahead
            speculate(b)
        front, stats = dispatch(qs, front, stats, gids)
        i = nxt
    if telemetry is not None:
        telemetry["stage_a_blocks"] = len(stage_a)
        telemetry["stage_a_dispatches"] = dispatch.dispatches
    return dataclasses.replace(
        prep, front=front, stats=stats,
        refined=prep.refined | frozenset(stage_a))


def run_cached(index: BlockIndex, queries: jax.Array, plan: QueryPlan, *,
               fetch: Callable[[int], jax.Array],
               speculate: Callable[[int], None] = lambda b: None,
               initial_threshold: jax.Array | None = None,
               prepared: PreparedSearch | None = None,
               pipeline_depth: int = 1, group_blocks: int = 1,
               telemetry: dict | None = None
               ) -> tuple[Frontier, SearchStats, PreparedSearch]:
    """The §5 host-level walk: the block-major schedule driven through a
    fetch callback (``storage.BlockCache`` in production), as a
    depth-D, group-G pipeline that degenerates to the serial walk at
    (D=1, G=1).

    Same schedule, same stopping rule, same ``panel_refine`` as the
    device block-major backend — only the block transport differs:
    ``fetch(b)`` must return the (C, n) device block (blocking only if a
    disk read is needed), ``speculate(b)`` starts a background read.

    ``pipeline_depth`` (D) is how many surviving schedule slots beyond
    the current group are speculated per iteration — D reads in flight
    behind the cache's reader pool instead of one.  ``group_blocks``
    (G) batches up to G consecutive surviving blocks (under the current
    host threshold) into ONE jitted dispatch (``_cached_refine_group``),
    and the walk syncs the threshold once per GROUP instead of once per
    block.  Both are threshold-speculative and exact by construction:
    the host threshold only decides which blocks are dispatched, it is
    stale by at most one group, and a stale bound only *weakens* host
    pruning — a block admitted stale meets the up-to-date device-side
    threshold inside the dispatch (the group scan carries the frontier),
    so it refines exactly what the serial walk would have refined (often
    nothing), and dist/idx/stats land bit-identical for any (D, G);
    only I/O (extra speculated-then-pruned fetches) can differ.

    ``telemetry`` (optional dict) is filled with host-side walk counters
    — ``syncs`` (host<->device threshold round trips), ``dispatches``,
    ``walk_blocks`` — so callers can verify the amortization
    (syncs ~= refined_blocks / G + 1).

    Returns ``(frontier, stats, state)``: the local frontier, the
    finalized work stats, and the walk's end state as a resumable
    ``PreparedSearch`` (pre-finalize stats; ``refined`` holds every
    block this run — and the run it resumed — actually refined).  I/O
    accounting belongs to the callback owner (the session).

    ``plan.deadline_blocks`` caps the blocks the walk refines AFTER
    stage A (the paper's approximate phase always completes, so an
    anytime answer is never worse than MESSI's approximate one); when
    the cap fires the returned frontier is the anytime answer and the
    returned state is its exact-resume continuation —
    ``serve.certify`` derives the certified error bound from it, and
    feeding it back through ``prepared`` upgrades to the exact answer
    bit-identically (same schedule order, same thresholds at every
    refine) while refining only the deferred blocks.

    ``prepared`` resumes from a ``PreparedSearch`` (produced by
    ``run_cached_stage_a`` — or a deadline-cut ``run_cached`` — for the
    same metric, index, queries, and k): query prep, block ranking, and
    stage A are skipped, and the walk never fetches or refines a block
    in ``prepared.refined`` again.
    """
    if plan.schedule != "block_major":
        raise ValueError("the cached backend walks the block-major "
                         f"schedule; got {plan.schedule!r}")
    _check_pipeline_knobs(pipeline_depth, group_blocks)
    n_blocks = index.n_blocks
    if prepared is None:
        prep = cached_setup(index, queries, plan)
        prep = _cached_stage_a(index, plan, prep,
                               np.asarray(prep.block_lb),  # sync: 1/batch
                               fetch, speculate, initial_threshold,
                               pipeline_depth=pipeline_depth,
                               group_blocks=group_blocks,
                               telemetry=telemetry)
    else:
        _check_prepared(prepared, plan, n_blocks, queries.shape[0])
        prep = prepared
    qs, front, block_lb, stats = (prep.qs, prep.front, prep.block_lb,
                                  prep.stats)
    done = prep.refined
    # one sync per batch: the host copy drives block ordering and the
    # suffix-min stop table; the walk itself then syncs once per GROUP
    # (the '# sync' sites below), which is the PR-9 amortization claim
    block_lb_h = np.asarray(block_lb)                            # sync
    dispatch = _GroupDispatcher(index, plan, block_lb, fetch,
                                initial_threshold)
    budget = plan.deadline_blocks        # refines left; None = unbounded

    # -- block-major walk over the surviving schedule -----------------
    order, sched_lb, _ = block_major_schedule(block_lb_h, xp=np)
    # slot_done[s]: schedule slot s already refined (stage A / a resumed
    # run) or consumed by this walk — the survivor scan masks it out
    slot_done = (np.isin(order, np.fromiter(done, np.int64, len(done)))
                 if done else np.zeros(n_blocks, dtype=bool))

    walked: list[int] = []               # blocks THIS walk refined
    n_syncs = 1
    thr_h = np.asarray(_bound(front, initial_threshold))              # sync
    ptr = 0
    while ptr < n_blocks:
        if budget is not None and len(walked) >= budget:
            break                       # deadline: answer is anytime now
        # vectorized survivor scan — one numpy op per threshold sync
        # replaces the per-slot Python pending() loop: a slot survives
        # if unconsumed and any query's scheduled LB beats the bound.
        # (No survivors <=> the suffix-min stopping rule fires: suffix
        # minima over pruned slots cannot beat thr either.)
        live = np.flatnonzero(~slot_done[ptr:] & np.any(
            sched_lb[:, ptr:] < thr_h[:, None], axis=0)) + ptr
        if live.size == 0:
            break                       # nothing later helps any query
        g = (group_blocks if budget is None
             else min(group_blocks, budget - len(walked)))
        take = live[:g]                 # this group's schedule slots
        gids = [int(order[s]) for s in take]
        for b in gids[1:]:
            # group members behind the head start reading now, so the
            # reader pool overlays them with the head's blocking fetch
            speculate(b)
        front, stats = dispatch(qs, front, stats, gids)           # async
        walked += gids
        slot_done[take] = True
        # depth-D threshold-speculative lookahead: the next D surviving
        # slots under the (now one group stale) bound start reading
        # while the device refines and the sync below waits.  The bound
        # only tightens, so a speculated slot pruned before its turn
        # just stays cached under its id for a later query/batch (a
        # deadline-cut walk leaves it warm for its own continuation).
        for s in live[g:g + pipeline_depth]:
            speculate(int(order[s]))
        thr_h = np.asarray(_bound(front, initial_threshold))  # 1 sync/group
        n_syncs += 1
        # slots in [ptr, take[-1]] not taken were pruned under a bound
        # that only tightened since — jump straight past the group
        ptr = int(take[-1]) + 1
    if telemetry is not None:
        telemetry.update(syncs=n_syncs, dispatches=dispatch.dispatches,
                         walk_blocks=len(walked),
                         pipeline_depth=pipeline_depth,
                         group_blocks=group_blocks)
    state = dataclasses.replace(prep, front=front, stats=stats,
                                refined=done | frozenset(walked))
    return front, plan.metric.finalize_stats(stats, index.capacity), state


def run_cached_stage_a(index: BlockIndex, queries: jax.Array,
                       plan: QueryPlan, *,
                       fetch: Callable[[int], jax.Array],
                       speculate: Callable[[int], None] = lambda b: None,
                       pipeline_depth: int = 1, group_blocks: int = 1
                       ) -> PreparedSearch:
    """Stage A only, on the cached backend: the approximate top-k after
    refining each query's best-envelope block.  The distributed
    out-of-core protocol min-reduces ``front.threshold()`` across shards
    (round 1), then threads the returned ``PreparedSearch`` back into
    ``run_cached`` so round 2 resumes instead of repeating stage A.
    ``pipeline_depth``/``group_blocks`` pipeline the stage-A chain the
    same way they pipeline the walk (see ``run_cached``)."""
    _check_pipeline_knobs(pipeline_depth, group_blocks)
    prep = cached_setup(index, queries, plan)
    return _cached_stage_a(index, plan, prep,
                           np.asarray(prep.block_lb),  # sync: 1/round
                           fetch, speculate, None,
                           pipeline_depth=pipeline_depth,
                           group_blocks=group_blocks)


# the dispatch mode is read at trace time inside these jitted entry
# points — ops.set_mode / ops.kernel_mode clears them on mode changes
ops.register_dispatch_cache(run)
ops.register_dispatch_cache(run_flat)
ops.register_dispatch_cache(_cached_refine_step)
ops.register_dispatch_cache(_cached_refine_group)
