"""Generic high-dimensional vector search — the paper's §V application
("our techniques are applicable to high-dimensional vectors in general ...
such as similarity search for deep learning embeddings").

A d-dim embedding is treated as a 'series' of length d: PAA segments become
contiguous dim groups. Z-normalization is OFF (embeddings are not shift/scale
invariant); unit-normalization gives cosine search since
||a - b||^2 = 2 - 2 cos(a, b) on the unit sphere — so the exact Euclidean
top-k frontier (DESIGN.md §4a) IS the exact cosine top-k, descending.

Used by examples/serve_with_index.py to serve k-NN over LM hidden states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import index as index_lib
from repro.core.index import BlockIndex
from repro.core.search import SearchResult
from repro.core.search import search as _search


def prep_vectors(v: jax.Array, unit_norm: bool = True) -> jax.Array:
    v = v.astype(jnp.float32)
    if unit_norm:
        v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-8)
        # rescale so per-dim values are ~N(0,1)-sized: iSAX breakpoints are
        # standard-normal quantiles and unit vectors (entries ~ 1/sqrt(d))
        # would otherwise collapse into the central regions. A global scale
        # preserves the NN ordering exactly.
        v = v * jnp.sqrt(jnp.float32(v.shape[-1]))
    return v


def build_vector_index(embs: jax.Array, *, w: int = 16, card: int = 256,
                       capacity: int = 512,
                       unit_norm: bool = True) -> BlockIndex:
    """embs (N, d) with d divisible by w."""
    return index_lib.build(prep_vectors(embs, unit_norm), w=w, card=card,
                           capacity=capacity, normalize=False)


def search_vectors(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                   unit_norm: bool = True, **kw) -> SearchResult:
    """Exact k-NN over the vector index. queries (Q, d) -> (Q, K) results."""
    q = prep_vectors(queries, unit_norm)
    return _search(index, q, k=k, normalize_queries=False, **kw)


def cosine_scores(res: SearchResult, dim: int) -> jax.Array:
    """(Q, K) cosine similarities from a unit-norm search result, descending.

    The index stores sqrt(dim)-scaled unit vectors, so the returned
    Euclidean distances satisfy d^2 = dim * (2 - 2 cos); invert that.
    Empty slots (idx == -1) map to -1 (the cosine floor).
    """
    cos = 1.0 - res.dist.astype(jnp.float32) ** 2 / (2.0 * dim)
    return jnp.where(res.idx >= 0, cos, -1.0)
