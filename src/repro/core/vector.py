"""Generic high-dimensional vector search — the paper's §V application
("our techniques are applicable to high-dimensional vectors in general ...
such as similarity search for deep learning embeddings").

A d-dim embedding is treated as a 'series' of length d: PAA segments become
contiguous dim groups. Z-normalization is OFF (embeddings are not shift/scale
invariant); unit-normalization gives cosine search since
||a - b||^2 = 2 - 2 cos(a, b) on the unit sphere — so the exact Euclidean
top-k frontier (DESIGN.md §4a) IS the exact cosine top-k, descending.

The preparation now lives in `core/engine.py` as ``prep_vectors`` /
the ``Cosine`` metric adapter; this module keeps the public faces.
Device-resident serving goes through `search_vectors`; out-of-core
serving through ``storage.SearchSession.search(qs, metric=Cosine())``
(used by examples/serve_with_index.py to serve k-NN over LM hidden
states).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import index as index_lib
from repro.core.engine import Cosine, prep_vectors  # noqa: F401 (re-export)
from repro.core.index import BlockIndex
from repro.core.search import SearchResult
from repro.core.search import search as _search


def build_vector_index(embs: jax.Array, *, w: int = 16, card: int = 256,
                       capacity: int = 512,
                       unit_norm: bool = True) -> BlockIndex:
    """embs (N, d) with d divisible by w."""
    return index_lib.build(prep_vectors(embs, unit_norm), w=w, card=card,
                           capacity=capacity, normalize=False)


def search_vectors(index: BlockIndex, queries: jax.Array, *, k: int = 1,
                   unit_norm: bool = True, **kw) -> SearchResult:
    """Exact k-NN over the vector index. queries (Q, d) -> (Q, K) results.

    Equivalent to a ``Cosine(unit_norm=...)`` plan on the query-major
    schedule; the preparation runs eagerly here (one pass per batch) so
    a caller can also prep once and hit the ED path directly.
    """
    q = prep_vectors(queries, unit_norm)
    return _search(index, q, k=k, normalize_queries=False, **kw)


def cosine_scores(res: SearchResult, dim: int) -> jax.Array:
    """(Q, K) cosine similarities from a unit-norm search result, descending.

    The index stores sqrt(dim)-scaled unit vectors, so the returned
    Euclidean distances satisfy d^2 = dim * (2 - 2 cos); invert that.
    Empty slots (idx == -1) map to -1 (the cosine floor).
    """
    cos = 1.0 - res.dist.astype(jnp.float32) ** 2 / (2.0 * dim)
    return jnp.where(res.idx >= 0, cos, -1.0)
