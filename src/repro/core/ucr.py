"""UCR-Suite-style parallel brute-force scan (the paper's serial-scan baseline).

The paper benchmarks against "UCR Suite-p", an in-memory parallel
implementation of the UCR Suite optimized sequential scan.  On TPU the
faithful analogue is a full batched-L2 sweep over the raw array on the MXU —
no lower bounds, no pruning.  (UCR's per-element early abandoning is dropped:
the paper itself replaces it with SIMD full computation, see DESIGN.md §2.)

Doubles as the correctness oracle for every index test: it carries the same
top-k Frontier as the index paths (DESIGN.md §4a), so its (Q, K) result is
the exact k-NN answer by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_lib
from repro.core import isax
from repro.core.frontier import INF
from repro.core.index import RAW_PAD
from repro.core.search import SearchResult, SearchStats
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("k", "chunk", "normalize"))
def search_scan(raw: jax.Array, queries: jax.Array, *, k: int = 1,
                chunk: int = 4096, normalize: bool = True,
                ids: jax.Array | None = None) -> SearchResult:
    """Exact k-NN by full scan. raw (N, n); queries (Q, n)."""
    n_series, n = raw.shape
    x = isax.znorm(raw) if normalize else raw.astype(jnp.float32)
    setup = frontier_lib.prepare(queries, k, normalize=normalize)
    q = setup.q
    qn = q.shape[0]
    if ids is None:
        ids = jnp.arange(n_series, dtype=jnp.int32)

    c = min(chunk, n_series)
    pad = (-n_series) % c
    if pad:
        x = jnp.concatenate([x, jnp.full((pad, n), RAW_PAD, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)], 0)
    nchunks = x.shape[0] // c

    def step(front, inp):
        raw_k, ids_k = inp
        d = ops.batch_l2(q, raw_k)                            # (Q, C)
        d = jnp.where(ids_k[None, :] >= 0, d, INF)
        # ids are globally unique and each chunk is seen once, so the
        # duplicate mask is provably unnecessary on this (baseline) path
        front = frontier_lib.insert_batch(
            front, d, jnp.broadcast_to(ids_k[None, :], (qn, c)),
            assume_unique=True)
        return front, None

    front, _ = jax.lax.scan(
        step, setup.frontier,
        (x.reshape(nchunks, c, n), ids.reshape(nchunks, c)))

    stats = SearchStats(
        blocks_visited=jnp.full((qn,), nchunks, jnp.int32),
        series_refined=jnp.full((qn,), n_series, jnp.int32),
        lb_series=jnp.zeros((qn,), jnp.int32),
        iters=jnp.asarray(nchunks, jnp.int32),
    )
    return SearchResult(dist=frontier_lib.result_dists(front),
                        idx=front.ids, stats=stats)


# batch_l2 dispatch mode is read at trace time — clear on mode changes
ops.register_dispatch_cache(search_scan)
