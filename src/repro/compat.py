"""Version-compatibility shims.

``jax.shard_map`` (with its ``check_vma`` flag) is the stable API this
codebase targets; on the pinned jax 0.4.x in the container it only exists
as ``jax.experimental.shard_map.shard_map`` with the flag spelled
``check_rep``.  Every module routes through this wrapper so the call
sites stay written against the stable API.
"""
from __future__ import annotations

import jax

try:                                     # jax >= 0.6: stable API
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                   # jax 0.4.x: experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
