"""The coalesced query-major priority walk (DESIGN.md §9).

``engine.run_cached`` walks block-major: one static schedule (ascending
min-over-queries envelope LB) shared by the whole batch.  Serving mixed
traffic wants the paper-faithful *query-major* order instead — each
query works through ITS OWN LB-ascending block list — without paying N
cold walks for N concurrent tenants.  This walk does both:

  * **priority**: at every step the fetched block is the most urgent
    query's next-best unrefined block — the global argmin, over all
    tenants' (query, block) pairs still able to improve a result, of
    the envelope lower bound.  Selecting that argmin IS per-query
    priority order: the winning query advances through its own ranking,
    and urgency decides the interleave (a dynamic generalization of the
    block-major schedule, which fixes the order up front and ignores
    thresholds).
  * **coalescing**: the fetched block refines EVERY tenant that could
    still need it, in one pass per tenant, and is marked refined for
    all of them — tenants whose queries no longer reach it (their
    bounds only tighten) skip it forever.  N tenants therefore fetch
    the union of their surviving block sets, not the sum.

Exactness is the engine's argument verbatim: a (query, block) pair is
only skipped once ``lb >= threshold``, and thresholds only tighten, so
no true k-NN member is ever dismissed — the final frontier is
bit-identical to each tenant running alone (the same candidates meet
the same ``panel_refine`` pipeline; only fetch order and count differ).

``budget`` bounds the walk's refines for anytime serving: when it
fires, each incomplete tenant's state is a deadline-cut walk state —
``serve.certify`` bounds its error, ``prepared=`` resumes it to exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.core import engine
from repro.core.index import BlockIndex


@dataclasses.dataclass
class TenantRun:
    """One admitted query batch's in-walk state.

    ``plan`` is the tenant's deadline-free plan (metric and k may differ
    across tenants sharing a walk); ``state`` is the evolving
    ``engine.PreparedSearch`` — stage-A-seeded on entry, the tenant's
    final (or anytime-resumable) state on exit.  ``complete`` is set
    once no unrefined block can improve any of the tenant's queries.
    """
    plan: engine.QueryPlan
    queries: jax.Array
    state: engine.PreparedSearch
    complete: bool = False


def prepare_tenant(index: BlockIndex, queries: jax.Array,
                   plan: engine.QueryPlan, *,
                   fetch: Callable[[int], jax.Array],
                   speculate: Callable[[int], None] = lambda b: None
                   ) -> TenantRun:
    """Admission: metric prep + block ranking + stage-A seeding.

    Stage A goes through the SHARED fetch callback, so tenants whose
    best-envelope blocks coincide already coalesce here — the second
    tenant's stage A is a cache hit, not a disk read.
    """
    state = engine.run_cached_stage_a(index, queries, plan,
                                      fetch=fetch, speculate=speculate)
    return TenantRun(plan=plan, queries=queries, state=state)


def coalesced_walk(index: BlockIndex, tenants: list[TenantRun], *,
                   fetch: Callable[[int], jax.Array],
                   speculate: Callable[[int], None] = lambda b: None,
                   budget: int | None = None) -> int:
    """Run the shared priority walk to completion (or ``budget`` refines).

    Mutates each tenant's ``state``/``complete`` in place; returns the
    number of blocks fetched+refined by the walk (excluding stage A).
    One device sync per tenant per refined block (the threshold
    read-back), same cadence as ``run_cached``; the next target's read
    is speculated before the sync so disk stays overlapped with compute.
    """
    if not tenants:
        return 0
    n_blocks = index.n_blocks
    # host-side walk state, per tenant: LB matrix, refined mask, thresholds
    lbs = [np.asarray(t.state.block_lb) for t in tenants]
    thrs = [np.asarray(t.state.front.threshold()) for t in tenants]
    refined = []
    for t in tenants:
        mask = np.zeros(n_blocks, dtype=bool)
        if t.state.refined:
            mask[np.fromiter(t.state.refined, dtype=np.int64)] = True
        refined.append(mask)
    walked = [set() for _ in tenants]     # beyond-stage-A refines, per tenant

    def urgency(i: int) -> np.ndarray:
        """(B,) tenant i's most urgent pending lb per block (inf = none)."""
        live = np.where(lbs[i] < thrs[i][:, None], lbs[i], np.inf)
        u = live.min(axis=0)
        u[refined[i]] = np.inf
        return u

    def pick() -> tuple[int, float]:
        glob = np.full(n_blocks, np.inf)
        for i in range(len(tenants)):
            if not tenants[i].complete:
                u = urgency(i)
                if np.isinf(u).all():
                    tenants[i].complete = True
                else:
                    glob = np.minimum(glob, u)
        b = int(np.argmin(glob))
        return b, float(glob[b])

    steps = 0
    while True:
        b_id, best = pick()
        if not np.isfinite(best):
            break                          # every tenant proved complete
        if budget is not None and steps >= budget:
            break                          # deadline: states are anytime now
        block = fetch(b_id)
        lo = index.slo[b_id]
        hi = index.shi[b_id]
        for i, t in enumerate(tenants):
            if refined[i][b_id]:
                continue                   # stage A (or an earlier step)
            refined[i][b_id] = True        # needed or not, never revisit:
            if not (lbs[i][:, b_id] < thrs[i]).any():
                continue                   # bounds only tighten from here
            metric = t.plan.metric
            needs = metric.filters and metric.needs_bounds
            front, stats = engine._cached_refine_step(
                metric, t.state.qs, t.state.front, t.state.stats,
                block, index.ids[b_id],
                lo if needs else None, hi if needs else None,
                t.state.block_lb[:, b_id], None,
                n=index.n, w=index.w)      # async dispatch
            t.state = dataclasses.replace(t.state, front=front, stats=stats)
            walked[i].add(b_id)
        steps += 1
        # speculate the next target under the PRE-sync thresholds (the
        # bound only tightens: a wasted read stays cached under its id),
        # then pay the one sync per tenant this block cost
        nxt, nbest = pick()
        if np.isfinite(nbest):
            speculate(nxt)
        for i, t in enumerate(tenants):
            if not t.complete:
                thrs[i] = np.asarray(t.state.front.threshold())

    for i, t in enumerate(tenants):
        t.state = dataclasses.replace(
            t.state, refined=t.state.refined | frozenset(walked[i]))
        if not t.complete:                 # re-check under final thresholds
            t.complete = bool(np.isinf(urgency(i)).all())
    return steps
