"""The coalesced query-major priority walk (DESIGN.md §9).

``engine.run_cached`` walks block-major: one static schedule (ascending
min-over-queries envelope LB) shared by the whole batch.  Serving mixed
traffic wants the paper-faithful *query-major* order instead — each
query works through ITS OWN LB-ascending block list — without paying N
cold walks for N concurrent tenants.  This walk does both:

  * **priority**: at every step the fetched block is the most urgent
    query's next-best unrefined block — the global argmin, over all
    tenants' (query, block) pairs still able to improve a result, of
    the envelope lower bound.  Selecting that argmin IS per-query
    priority order: the winning query advances through its own ranking,
    and urgency decides the interleave (a dynamic generalization of the
    block-major schedule, which fixes the order up front and ignores
    thresholds).
  * **coalescing**: the fetched block refines EVERY tenant that could
    still need it, in one pass per tenant, and is marked refined for
    all of them — tenants whose queries no longer reach it (their
    bounds only tighten) skip it forever.  N tenants therefore fetch
    the union of their surviving block sets, not the sum.

Exactness is the engine's argument verbatim: a (query, block) pair is
only skipped once ``lb >= threshold``, and thresholds only tighten, so
no true k-NN member is ever dismissed — the final frontier is
bit-identical to each tenant running alone (the same candidates meet
the same ``panel_refine`` pipeline; only fetch order and count differ).

``budget`` bounds the walk's refines for anytime serving: when it
fires, each incomplete tenant's state is a deadline-cut walk state —
``serve.certify`` bounds its error, ``prepared=`` resumes it to exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.core import engine
from repro.core.index import BlockIndex


@dataclasses.dataclass
class TenantRun:
    """One admitted query batch's in-walk state.

    ``plan`` is the tenant's deadline-free plan (metric and k may differ
    across tenants sharing a walk); ``state`` is the evolving
    ``engine.PreparedSearch`` — stage-A-seeded on entry, the tenant's
    final (or anytime-resumable) state on exit.  ``complete`` is set
    once no unrefined block can improve any of the tenant's queries.
    """
    plan: engine.QueryPlan
    queries: jax.Array
    state: engine.PreparedSearch
    complete: bool = False


def prepare_tenant(index: BlockIndex, queries: jax.Array,
                   plan: engine.QueryPlan, *,
                   fetch: Callable[[int], jax.Array],
                   speculate: Callable[[int], None] = lambda b: None,
                   pipeline_depth: int = 1, group_blocks: int = 1
                   ) -> TenantRun:
    """Admission: metric prep + block ranking + stage-A seeding.

    Stage A goes through the SHARED fetch callback, so tenants whose
    best-envelope blocks coincide already coalesce here — the second
    tenant's stage A is a cache hit, not a disk read.
    ``pipeline_depth``/``group_blocks`` pipeline the tenant's own
    stage-A chain exactly as in ``run_cached`` (answers unchanged).
    """
    state = engine.run_cached_stage_a(index, queries, plan,
                                      fetch=fetch, speculate=speculate,
                                      pipeline_depth=pipeline_depth,
                                      group_blocks=group_blocks)
    return TenantRun(plan=plan, queries=queries, state=state)


def coalesced_walk(index: BlockIndex, tenants: list[TenantRun], *,
                   fetch: Callable[[int], jax.Array],
                   speculate: Callable[[int], None] = lambda b: None,
                   budget: int | None = None,
                   pipeline_depth: int = 1, group_blocks: int = 1) -> int:
    """Run the shared priority walk to completion (or ``budget`` refines).

    Mutates each tenant's ``state``/``complete`` in place; returns the
    number of blocks fetched+refined by the walk (excluding stage A).

    The walk is pipelined exactly like ``engine.run_cached``: each step
    picks the ``group_blocks`` most urgent surviving blocks under the
    CURRENT host thresholds (stable urgency order — ties fall to the
    lowest block id, so G=1 degenerates to today's argmin pick), batches
    each tenant's share of the group into one jitted dispatch, then
    speculates the next ``pipeline_depth`` targets before paying ONE
    threshold sync per tenant per group.  Stale thresholds only admit
    extra blocks, and the device-side active mask inside each dispatch
    re-checks the carried frontier's threshold, so final dist/idx stay
    bit-identical to the serial walk (and to each tenant alone).  The
    work counters may differ under G>1: unlike ``run_cached``'s static
    schedule, this walk's fetch order is threshold-dynamic, so grouping
    can legitimately change which interleave (and how much masked work)
    produced the same exact answer.  ``budget`` still counts blocks: a
    partial final group is cut to fit.
    """
    if not tenants:
        return 0
    engine._check_pipeline_knobs(pipeline_depth, group_blocks)
    n_blocks = index.n_blocks
    # host-side walk state, per tenant: LB matrix, refined mask, thresholds
    lbs = [np.asarray(t.state.block_lb) for t in tenants]
    thrs = [np.asarray(t.state.front.threshold()) for t in tenants]
    refined = []
    for t in tenants:
        mask = np.zeros(n_blocks, dtype=bool)
        if t.state.refined:
            mask[np.fromiter(t.state.refined, dtype=np.int64)] = True
        refined.append(mask)
    walked = [set() for _ in tenants]     # beyond-stage-A refines, per tenant

    def urgency(i: int) -> np.ndarray:
        """(B,) tenant i's most urgent pending lb per block (inf = none)."""
        live = np.where(lbs[i] < thrs[i][:, None], lbs[i], np.inf)
        u = live.min(axis=0)
        u[refined[i]] = np.inf
        return u

    def pick_many(g: int) -> list[int]:
        """The ``g`` most urgent surviving blocks, urgency-ascending.

        Stable: ties keep ascending block-id order, so ``g=1`` is
        exactly the old ``np.argmin`` pick.  Flags tenants whose
        urgency went all-inf as complete, like the old ``pick``.
        """
        glob = np.full(n_blocks, np.inf)
        for i in range(len(tenants)):
            if not tenants[i].complete:
                u = urgency(i)
                if np.isinf(u).all():
                    tenants[i].complete = True
                else:
                    glob = np.minimum(glob, u)
        live = np.flatnonzero(np.isfinite(glob))
        if live.size == 0:
            return []
        return [int(b) for b in
                live[np.argsort(glob[live], kind="stable")[:g]]]

    # per-tenant group dispatchers share one fetched-this-step map, so
    # each block is read once for the whole fleet and stacked per tenant
    fetched: dict[int, jax.Array] = {}
    disps = [engine._GroupDispatcher(index, t.plan, t.state.block_lb,
                                     fetched.__getitem__, None)
             for t in tenants]

    steps = 0
    while True:
        gids = pick_many(group_blocks)
        if not gids:
            break                          # every tenant proved complete
        if budget is not None:
            if steps >= budget:
                break                      # deadline: states are anytime now
            gids = gids[:budget - steps]   # partial final group: cut to fit
        for b in gids[1:]:
            speculate(b)                   # overlap the group's own reads
        fetched.clear()
        for b in gids:
            fetched[b] = fetch(b)
        for i, t in enumerate(tenants):
            sel = [b for b in gids if not refined[i][b]]
            for b in sel:
                refined[i][b] = True       # needed or not, never revisit:
            # host-side cut under this tenant's (possibly one-group-
            # stale) threshold; the device mask re-checks per block
            sel = [b for b in sel if (lbs[i][:, b] < thrs[i]).any()]
            if not sel:
                continue                   # bounds only tighten from here
            front, stats = disps[i](t.state.qs, t.state.front,
                                    t.state.stats, sel)   # async dispatch
            t.state = dataclasses.replace(t.state, front=front, stats=stats)
            walked[i].update(sel)
        steps += len(gids)
        # speculate the next depth-D targets under the PRE-sync
        # thresholds (the bound only tightens: a wasted read stays
        # cached under its id), then pay the one sync per tenant this
        # GROUP cost — the amortization that motivates group_blocks
        for b in pick_many(pipeline_depth):
            speculate(b)
        for i, t in enumerate(tenants):
            if not t.complete:
                thrs[i] = np.asarray(t.state.front.threshold())

    for i, t in enumerate(tenants):
        t.state = dataclasses.replace(
            t.state, refined=t.state.refined | frozenset(walked[i]))
        if not t.complete:                 # re-check under final thresholds
            t.complete = bool(np.isinf(urgency(i)).all())
    return steps
