"""Admission coalescing for concurrent sessions (DESIGN.md §9).

Under mixed traffic, one ``SearchSession.search`` at a time means exact
queries queue behind each other.  The coalescer is the admission layer
in front of the session: concurrent callers ``submit()`` their batches
and get back a ``Ticket`` immediately; a ``drain()`` admits everything
pending as one fleet of tenants and answers them through a single
``serve.coalesced_walk`` — every block fetched once for all tenants
that still need it, through the session's one ``BlockCache``.

Submissions with the SAME plan (metric, k, filter flags) are merged
into one tenant — their queries ride one (ΣQ, n) panel through every
refine, the device-side half of coalescing — and split back into
per-ticket rows at resolution.  Submissions with different plans stay
separate tenants but still share every fetch.

``Ticket.result()`` blocks until its drain has run; the first caller to
ask becomes the drainer for the whole admitted window (everyone else
waits on their event), so a fleet of threads that all submit-then-wait
serves itself with zero extra orchestration.  Accounting: one drain is
one bill — the first touch of each block across ALL tenants decides
hit vs miss once, so ``blocks_fetched`` measures the coalesced union,
directly comparable against N isolated sessions fetching the sum.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp

from repro.analysis import sanitize
from repro.core import engine
from repro.core import frontier as frontier_lib
from repro.core.frontier import Frontier, SearchStats
from repro.serve.anytime import AnytimeResult, certify
from repro.serve.scheduler import TenantRun, coalesced_walk, prepare_tenant


class Ticket:
    """Handle for one submitted query batch.

    ``result()`` returns the batch's ``OocSearchResult`` (exact) or
    ``serve.AnytimeResult`` (a budgeted drain cut this tenant short),
    draining the session's pending admissions first if nobody else has.
    """

    def __init__(self, coalescer: "AdmissionCoalescer",
                 queries: jax.Array, plan: engine.QueryPlan):
        self._coalescer = coalescer
        self.queries = queries
        self.plan = plan
        self._done = threading.Event()
        self._result = None
        self._error: BaseException | None = None

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def _resolve(self, result=None, error=None) -> None:
        self._result = result
        self._error = error
        self._done.set()

    def result(self, timeout: float | None = None):
        if not self._done.is_set():
            # either we become the drainer, or we wait out whoever is
            # mid-drain holding our ticket and find it resolved after
            self._coalescer.drain()
        if not self._done.wait(timeout):
            raise TimeoutError("ticket not resolved within timeout")
        if self._error is not None:
            raise self._error
        return self._result


def _slice_state(state: engine.PreparedSearch, sl: slice
                 ) -> engine.PreparedSearch:
    """Rows ``sl`` of a merged tenant's walk state, as a standalone
    resumable state for that ticket's queries.  Every leaf is per-query
    on its leading axis (metric aux arrays included); ``refined`` is
    shared — those blocks were refined against the full merged panel,
    so the sliced frontier rows already reflect them."""
    qs = engine.QueryState(q=state.qs.q[sl],
                           aux=tuple(a[sl] for a in state.qs.aux))
    return engine.PreparedSearch(
        qs=qs,
        front=Frontier(dists=state.front.dists[sl], ids=state.front.ids[sl]),
        block_lb=state.block_lb[sl],
        stats=SearchStats(blocks_visited=state.stats.blocks_visited[sl],
                          series_refined=state.stats.series_refined[sl],
                          lb_series=state.stats.lb_series[sl],
                          iters=state.stats.iters),
        refined=state.refined)


@sanitize.guarded
class AdmissionCoalescer:
    """Pending-submission queue + the coalesced drain, bound to one
    ``storage.SearchSession`` (sessions construct one lazily on first
    ``submit``)."""

    def __init__(self, session):
        self.session = session
        self._pending: list[Ticket] = []      # guarded by: _admit_lock
        self._admit_lock = sanitize.create_lock()
        # serializes drains; _run only ever executes under it
        self._drain_lock = sanitize.create_lock()

    def submit(self, queries: jax.Array, plan: engine.QueryPlan) -> Ticket:
        if plan.deadline_blocks is not None:
            raise ValueError("per-ticket deadlines are not supported: the "
                             "deadline is a property of the drain "
                             "(drain(deadline_blocks=...)) — the walk's "
                             "budget is shared by construction")
        t = Ticket(self, jnp.asarray(queries), plan)
        with self._admit_lock:
            self._pending.append(t)
        return t

    def drain(self, *, deadline_blocks: int | None = None) -> list[Ticket]:
        """Answer every pending submission in one coalesced walk.

        Serialized: concurrent callers queue on the drain lock, and a
        ticket submitted during a running drain lands in the next one.
        With ``deadline_blocks`` the walk refines at most that many
        blocks beyond the per-tenant stage A; tenants it finished get
        exact results, the rest get certified ``AnytimeResult``s whose
        ``refine_to_exact`` resumes through this same session.
        """
        if deadline_blocks is not None and deadline_blocks < 1:
            raise ValueError(f"deadline_blocks must be >= 1 (or None for "
                             f"an exact drain), got {deadline_blocks}")
        with self._drain_lock:
            with self._admit_lock:
                batch, self._pending = self._pending, []
            if batch:
                try:
                    self._run(batch, deadline_blocks)
                except BaseException as e:
                    for t in batch:
                        if not t.done:
                            t._resolve(error=e)
                    raise
            return batch

    # -- the drain body --------------------------------------------------

    def _run(self, batch: list[Ticket], deadline_blocks: int | None) -> None:
        # caller holds _drain_lock
        from repro.storage.cache import (PreparedRound, _TouchTracker,
                                         _query_signature)
        session = self.session
        index = session.index

        # one bill per drain: first touch across ALL tenants decides
        # hit vs miss once (the coalescing is what the bill measures)
        tracker = _TouchTracker(session.cache)
        fetch, speculate = tracker.fetch, tracker.speculate

        # merge same-plan tickets into one tenant (one device panel);
        # remember each ticket's row slice for the split at resolution
        groups: dict[engine.QueryPlan, list[Ticket]] = {}
        for t in batch:
            groups.setdefault(t.plan, []).append(t)
        tenants: list[TenantRun] = []
        rows: list[list[tuple[Ticket, slice]]] = []
        # the drain inherits the session's walk pipeline (depth-D
        # speculation, group-G batched refines — engine.run_cached's
        # knobs); answers are bit-identical at every setting
        d, g = session.pipeline_depth, session.group_blocks
        for plan, tickets in groups.items():
            qs = (tickets[0].queries if len(tickets) == 1 else
                  jnp.concatenate([t.queries for t in tickets], axis=0))
            tenants.append(prepare_tenant(index, qs, plan,
                                          fetch=fetch, speculate=speculate,
                                          pipeline_depth=d, group_blocks=g))
            sls, at = [], 0
            for t in tickets:
                qn = t.queries.shape[0]
                sls.append((t, slice(at, at + qn)))
                at += qn
            rows.append(sls)

        coalesced_walk(index, tenants, fetch=fetch, speculate=speculate,
                       budget=deadline_blocks,
                       pipeline_depth=d, group_blocks=g)
        session.cache.drain()            # settle speculations into this bill
        union = set().union(*(t.state.refined for t in tenants))
        io = session._bill(tracker, batches=len(batch),
                           blocks_refined=len(union))

        for tenant, sls in zip(tenants, rows):
            display = tenant.plan.metric.finalize_stats(
                tenant.state.stats, index.capacity)
            dist = frontier_lib.result_dists(tenant.state.front)
            for ticket, sl in sls:
                ticket._resolve(self._make_result(
                    ticket, tenant, sl, dist, display, io,
                    _query_signature, PreparedRound))

    def _make_result(self, ticket: Ticket, tenant: TenantRun, sl: slice,
                     dist, display_stats, io, _query_signature,
                     PreparedRound):
        from repro.storage.ooc_search import OocSearchResult
        stats = SearchStats(
            blocks_visited=display_stats.blocks_visited[sl],
            series_refined=display_stats.series_refined[sl],
            lb_series=display_stats.lb_series[sl],
            iters=display_stats.iters)
        if tenant.complete:
            return OocSearchResult(dist=dist[sl],
                                   idx=tenant.state.front.ids[sl],
                                   stats=stats, io=io)
        state = _slice_state(tenant.state, sl)
        resume = PreparedRound(self.session, ticket.plan,
                               _query_signature(ticket.queries), state,
                               carry_blocks=0, carry_bytes=0,
                               touched=set(), hits=0)
        return AnytimeResult(dist=dist[sl], idx=tenant.state.front.ids[sl],
                             stats=stats, io=io, certificate=certify(state),
                             resume=resume, queries=ticket.queries)
