"""Certified anytime answers (DESIGN.md §9).

The MESSI/ParIS+ answer discipline is approximate-then-exact: return a
good answer immediately, certify or refine it as budget allows.  A
deadline-cut walk (``engine.run_cached`` with ``deadline_blocks``, or a
budgeted ``serve.coalesced_walk``) ends holding everything needed to
make that discipline *certified*:

  * the frontier's distances are EXACT distances of real candidates, so
    the reported k-th distance is an upper bound on the true k-th
    distance — for any deadline, by construction;
  * every unrefined block's envelope lower bound under-estimates every
    member's distance (the index's no-false-dismissal bound), so the
    minimum surviving envelope LB over the deferred blocks, clipped at
    the reported k-th, is a lower bound on the true k-th.

``certify`` turns a walk's end state into that two-sided
``AnytimeCertificate``; when the interval is empty the anytime answer
IS the exact answer and the certificate says so.  ``AnytimeResult``
carries the certificate next to the answer plus the walk's resumable
``PreparedSearch``; ``refine_to_exact()`` feeds it back through the
session, upgrading to the exact answer bit-identically (same schedule,
same thresholds at every refine — the PR-5 resume argument) while
refining only the deferred blocks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import numpy as np

from repro.core import engine
from repro.core.frontier import INF, SearchStats
from repro.storage.ooc_search import IOStats


class AnytimeCertificate(NamedTuple):
    """Two-sided per-query bound on the true k-th distance (sqrt domain).

    ``lower[q] <= true_kth[q] <= upper[q]`` — exact by construction:
    ``upper`` is the reported answer's own k-th distance (an exact
    distance of a real candidate; INF while fewer than k real candidates
    have been seen), ``lower`` is the minimum envelope lower bound over
    blocks not yet refined, clipped into [0, upper].  ``exact[q]`` means
    the interval is empty — no deferred block can beat the reported
    k-th, so the anytime answer is certifiably the exact one.
    ``blocks_deferred[q]`` counts the deferred blocks that could still
    matter (envelope LB below ``upper``) — the remaining refine budget
    ``refine_to_exact`` will spend, at most.
    """
    upper: np.ndarray            # (Q,) reported k-th distance (sqrt'd)
    lower: np.ndarray            # (Q,) certified floor on the true k-th
    exact: np.ndarray            # (Q,) bool: answer certified exact
    blocks_deferred: np.ndarray  # (Q,) int: deferred blocks below upper

    @property
    def gap(self) -> np.ndarray:
        """(Q,) certified uncertainty ``upper - lower``; 0 when exact."""
        return self.upper - self.lower


def certify(state: engine.PreparedSearch) -> AnytimeCertificate:
    """Certificate for a walk end state (``run_cached``'s third return).

    Pure host arithmetic over state the walk already holds: the frontier
    (exact candidate distances), the (Q, B) envelope lower-bound matrix,
    and the set of refined block ids.  Comparisons happen in the squared
    domain the walk prunes in; the reported bounds are sqrt'd to match
    ``SearchResult.dist``.
    """
    dists = np.asarray(state.front.dists)            # (Q, K) squared
    ids = np.asarray(state.front.ids)
    block_lb = np.asarray(state.block_lb)            # (Q, B) squared
    qn, n_blocks = block_lb.shape

    upper_sq = dists[:, -1]                          # k-th best so far
    deferred = np.ones(n_blocks, dtype=bool)
    if state.refined:
        deferred[np.fromiter(state.refined, dtype=np.int64)] = False
    if deferred.any():
        rem_sq = block_lb[:, deferred].min(axis=1)   # (Q,)
        n_live = np.sum(block_lb[:, deferred] < upper_sq[:, None], axis=1)
    else:
        rem_sq = np.full(qn, np.float32(INF))
        n_live = np.zeros(qn, dtype=np.int64)
    exact = rem_sq >= upper_sq
    lower_sq = np.clip(rem_sq, 0.0, upper_sq)

    # report in the sqrt domain of SearchResult.dist; a frontier slot
    # still empty (id < 0) keeps the INF convention rather than
    # sqrt(float32 max)
    full = ids[:, -1] >= 0
    upper = np.where(full, np.sqrt(upper_sq), np.float32(INF))
    lower = np.where(full, np.sqrt(lower_sq),
                     np.sqrt(np.maximum(rem_sq, 0.0)))
    return AnytimeCertificate(upper=upper.astype(np.float32),
                              lower=lower.astype(np.float32),
                              exact=exact,
                              blocks_deferred=n_live.astype(np.int64))


class AnytimeResult(NamedTuple):
    """An anytime answer: the current top-k, its certificate, and the
    continuation that upgrades it to exact.

    Leading fields match ``storage.OocSearchResult`` (an anytime answer
    drops into any consumer of one); ``certificate`` bounds the true
    k-th distance; ``resume`` is the session-scoped continuation
    (``storage.PreparedRound``).  ``refine_to_exact()`` consumes the
    continuation: bit-identical dist/idx/stats to an exact cold search
    of the same queries, refining only the blocks the deadline deferred.
    """
    dist: jax.Array              # (Q, K) current k-NN distances, ascending
    idx: jax.Array               # (Q, K) candidate ids; -1 = empty slot
    stats: SearchStats
    io: IOStats
    certificate: AnytimeCertificate
    resume: object               # storage.PreparedRound (None once consumed)
    queries: jax.Array           # the submitted batch, for the continuation

    @property
    def nn_dist(self) -> jax.Array:
        return self.dist[..., 0]

    @property
    def nn_idx(self) -> jax.Array:
        return self.idx[..., 0]

    def refine_to_exact(self):
        """Resume the deferred walk to the exact answer. -> OocSearchResult.

        Runs on the session that produced this answer, through the same
        cache — blocks the anytime phase fetched (or speculated) are
        served warm.  The result is bit-identical to a from-scratch
        exact search of the same queries (dist, idx, AND cumulative
        stats), but this continuation fetches and refines strictly fewer
        blocks: everything the anytime phase refined is skipped.  The
        continuation's ``io`` is its own bill — the anytime phase's
        reads were already billed to the anytime result.
        """
        r = self.resume
        if r is None or r.consumed:
            raise ValueError("this anytime answer's continuation is already "
                             "consumed — refine_to_exact resumes exactly "
                             "once (keep the returned exact result)")
        return r.session.search(self.queries, k=r.plan.k,
                                metric=r.plan.metric, prepared=r)
