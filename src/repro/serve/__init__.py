"""Multi-tenant serving subsystem (DESIGN.md §9).

The serving layer over the PR-4 engine and the PR-3 block cache:

  * ``scheduler`` — the coalesced query-major priority walk: concurrent
    tenants' exact walks interleaved by urgency over ONE cache, every
    block fetched once for all tenants that need it;
  * ``coalescer`` — admission: ``SearchSession.submit`` queues batches
    as ``Ticket``s, ``drain`` answers everything pending in one walk;
  * ``anytime`` — certified anytime answers: ``certify`` turns any
    deadline-cut walk state into a two-sided bound on the true k-th
    distance, ``AnytimeResult.refine_to_exact`` upgrades to the exact
    answer without repeating work.

Entry points are on ``storage.SearchSession`` (``submit``/``drain``,
``search(deadline_blocks=...)``); this package holds the machinery.
"""
from repro.serve.anytime import (AnytimeCertificate, AnytimeResult,  # noqa: F401
                                 certify)
from repro.serve.coalescer import AdmissionCoalescer, Ticket  # noqa: F401
from repro.serve.scheduler import (TenantRun, coalesced_walk,  # noqa: F401
                                   prepare_tenant)
