"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init; smoke
tests and benches run on the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) ("data", "model") single pod; (2, 16, 16) ("pod", "data",
    "model") for the 512-chip two-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic rescale)."""
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple[str, ...]:
    """Every non-'model' axis is a data axis (pod included)."""
    return tuple(a for a in mesh.axis_names if a != "model")
