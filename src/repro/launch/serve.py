"""Serving driver: prefill + batched decode for any assigned arch — or,
with ``--search-index``, multi-tenant similarity-search serving.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Demonstrates the full serve path the decode_* dry-run cells lower: cache
init -> prefill -> decode loop (greedy).

Search-serving mode (DESIGN.md §9) takes a saved data-series index and
drives the multi-tenant subsystem against it: ``--tenants`` threads each
submit a query batch, one coalesced drain answers all of them, and with
``--deadline-blocks`` the drain returns certified anytime answers that
are then refined to exact:

    PYTHONPATH=src python -m repro.launch.serve \
        --search-index /path/to/idx.dsix --tenants 4 [--deadline-blocks 8]
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import common, transformer
from repro.train.step import make_prefill_step, make_serve_step


def serve_search(args) -> int:
    """Multi-tenant search serving against a saved index."""
    from repro import serve, storage

    index = storage.open_index(args.search_index)
    print(f"opened {args.search_index}: {index.n_real} x {index.n} series, "
          f"{index.n_blocks} blocks on disk")
    rng = np.random.default_rng(args.seed)
    host = index.host_raw
    # tenant traffic: perturbed members of the corpus itself, one batch
    # per tenant, drawn from different blocks so the walks overlap only
    # partially (the interesting coalescing regime)
    loads = []
    for t in range(args.tenants):
        b = rng.integers(0, index.n_blocks)
        base = np.asarray(host.fetch(b))[
            rng.choice(index.capacity, args.batch, replace=False)]
        loads.append(jnp.asarray(
            base + 0.05 * rng.standard_normal(base.shape).astype(np.float32)))

    with storage.SearchSession(index, cache_blocks=args.cache_blocks) as s:
        # compile warmup (jit cache is global, block cache is per-session)
        for q in loads:
            s.submit(q, k=args.k)
        s.drain()

    with storage.SearchSession(index, cache_blocks=args.cache_blocks) as s:
        results = [None] * args.tenants
        admitted = threading.Barrier(args.tenants)

        def tenant(i):
            t = s.submit(loads[i], k=args.k)
            admitted.wait()
            results[i] = t.result()

        threads = [threading.Thread(target=tenant, args=(i,))
                   for i in range(args.tenants)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = (time.perf_counter() - t0) * 1e3
        print(f"{args.tenants} tenants x {args.batch} queries (top-{args.k})"
              f": {wall:.1f} ms wall, {s.blocks_fetched} disk blocks for "
              f"the whole fleet ({index.n_blocks} in the index), "
              f"{100 * s.hit_rate:.0f}% coalesced hit-rate")

    if args.deadline_blocks:
        with storage.SearchSession(index,
                                   cache_blocks=args.cache_blocks) as s:
            t0 = time.perf_counter()
            a = s.search(loads[0], k=args.k,
                         deadline_blocks=args.deadline_blocks)
            anytime_ms = (time.perf_counter() - t0) * 1e3
            c = a.certificate
            print(f"anytime (deadline {args.deadline_blocks} blocks): "
                  f"{anytime_ms:.1f} ms, certified gap "
                  f"{float(c.gap.mean()):.3f} mean / "
                  f"{float(c.gap.max()):.3f} max, "
                  f"{int(c.exact.sum())}/{len(c.exact)} queries already "
                  f"certified exact")
            t0 = time.perf_counter()
            ex = a.refine_to_exact()
            print(f"refine_to_exact: +{(time.perf_counter()-t0)*1e3:.1f} ms,"
                  f" {ex.io.blocks_fetched} further disk blocks "
                  f"(answers now exact; certificate verified "
                  f"{bool((np.asarray(ex.dist)[:, -1] <= c.upper + 1e-5).all())})")
            assert isinstance(a, serve.AnytimeResult)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--search-index", default=None,
                    help="saved .dsix index: serve multi-tenant similarity "
                         "search against it instead of LM decode")
    ap.add_argument("--tenants", type=int, default=4,
                    help="concurrent tenant threads (search mode)")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--cache-blocks", type=int, default=64)
    ap.add_argument("--deadline-blocks", type=int, default=None,
                    help="also demo a certified anytime answer with this "
                         "refine budget, then refine it to exact")
    args = ap.parse_args(argv)

    if args.search_index:
        return serve_search(args)
    if not args.arch:
        ap.error("--arch is required (or pass --search-index)")

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = common.build_params(transformer.param_specs(cfg), key)

    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.enc_dec:
        batch = {"frames": jnp.asarray(
                     rng.standard_normal((b, s, cfg.d_model))
                     .astype(np.float32) * 0.1),
                 "dec_tokens": prompt[:, :min(s, cfg.decoder_len // 2)]}
        max_len = s
        start_pos = batch["dec_tokens"].shape[1]
    else:
        batch = {"tokens": prompt}
        start_pos = s

    cache = transformer.init_cache(cfg, b, max_len, dtype=jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(start_pos + i, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={b} prompt={s} gen={len(out)}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode*1e3/max(1,len(out)-1):.1f} ms/token")
    print("sample tokens:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
