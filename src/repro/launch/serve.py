"""Serving driver: prefill + batched decode for any assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Demonstrates the full serve path the decode_* dry-run cells lower: cache
init -> prefill -> decode loop (greedy).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import common, transformer
from repro.train.step import make_prefill_step, make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = common.build_params(transformer.param_specs(cfg), key)

    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.enc_dec:
        batch = {"frames": jnp.asarray(
                     rng.standard_normal((b, s, cfg.d_model))
                     .astype(np.float32) * 0.1),
                 "dec_tokens": prompt[:, :min(s, cfg.decoder_len // 2)]}
        max_len = s
        start_pos = batch["dec_tokens"].shape[1]
    else:
        batch = {"tokens": prompt}
        start_pos = s

    cache = transformer.init_cache(cfg, b, max_len, dtype=jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(start_pos + i, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={b} prompt={s} gen={len(out)}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode*1e3/max(1,len(out)-1):.1f} ms/token")
    print("sample tokens:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
