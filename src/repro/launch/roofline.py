"""Roofline terms from a compiled dry-run artifact (no hardware needed).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory     = HLO_bytes_per_device / HBM_bw             [s]
    collective = per-device collective bytes / link_bw     [s]

FLOPs / bytes / collective bytes come from the loop-aware HLO text analysis
in ``hlo_analysis.py`` (XLA's own cost_analysis counts while bodies once —
useless for scanned programs; both numbers are recorded so the undercount is
visible).  The compiled module is the per-device partitioned program, so
everything is per-chip already; all-reduce counts 2x its tensor (ring
reduce-scatter + all-gather), other collectives 1x.

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s per ICI link.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.launch import hlo_analysis

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device (loop-aware)
    dot_flops: float             # matmul-only portion
    flops_xla: float             # XLA cost_analysis (loop-undercounted)
    bytes_hbm: float             # per device
    bytes_coll: float            # per device
    coll_by_op: dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6ND (train) / 2ND (fwd) global
    useful_ratio: float          # model_flops / (flops * chips)
    warnings: list[str]

    def table_row(self) -> dict[str, Any]:
        return {
            "flops_per_dev": self.flops, "dot_flops_per_dev": self.dot_flops,
            "flops_xla_ca": self.flops_xla,
            "bytes_per_dev": self.bytes_hbm,
            "coll_bytes_per_dev": self.bytes_coll,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "coll_by_op": self.coll_by_op,
            "warnings": self.warnings,
        }


def analyze(compiled, *, n_chips: int, model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):                 # older jax returns [dict]
        ca = ca[0]
    totals = hlo_analysis.analyze_text(compiled.as_text())
    flops = float(totals.flops)
    bytes_hbm = float(totals.bytes)
    bytes_coll = float(totals.coll_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_hbm / HBM_BW
    collective_s = bytes_coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / (flops * n_chips) if flops else 0.0
    return Roofline(flops=flops, dot_flops=float(totals.dot_flops),
                    flops_xla=float(ca.get("flops", 0.0)),
                    bytes_hbm=bytes_hbm, bytes_coll=bytes_coll,
                    coll_by_op=dict(totals.coll_by_op or {}),
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, bottleneck=bottleneck,
                    model_flops=model_flops, useful_ratio=useful,
                    warnings=list(totals.warnings or []))


def model_flops_for(cfg, shape_name: str) -> float:
    """6·N·D for training, 2·N·D for prefill, 2·N·B per decoded token
    (N = active params for MoE)."""
    from repro.configs.base import SHAPES, active_params
    cell = SHAPES[shape_name]
    n = active_params(cfg)
    if cell.kind == "train":
        return 6.0 * n * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        return 2.0 * n * cell.seq_len * cell.global_batch
    return 2.0 * n * cell.global_batch            # one token per sequence
