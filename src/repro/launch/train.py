"""Training driver: any assigned arch, any mesh, synthetic or file data.

Fault tolerance wired in (DESIGN.md §6): resume-from-latest-checkpoint,
SIGTERM -> synchronous final checkpoint, NaN-step skipping (inside the jitted
step), keep-last-k checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --smoke --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Multi-device runs shard the batch over the data axes of ``--mesh dxm``
(e.g. ``--mesh 4x2`` under XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import synthetic_token_batches
from repro.launch.mesh import data_axes_of, make_mesh
from repro.models import common, transformer
from repro.train import Checkpointer, make_train_step
from repro.train.optimizer import opt_init


def make_batch_fn(cfg, batch: int, seq: int, seed: int = 0):
    gen = synthetic_token_batches(batch=batch, seq_len=seq, vocab=cfg.vocab,
                                  seed=seed)
    rng = np.random.default_rng(seed + 1)

    def next_batch(step: int):
        tokens = jnp.asarray(next(gen)["tokens"])
        if cfg.enc_dec:
            return {"frames": jnp.asarray(
                        rng.standard_normal((batch, seq, cfg.d_model))
                        .astype(np.float32) * 0.1),
                    "dec_tokens": tokens[:, :cfg.decoder_len]}
        if cfg.family == "vlm":
            p = min(cfg.n_patches, seq // 2)
            return {"patches": jnp.asarray(
                        rng.standard_normal((batch, p, cfg.d_model))
                        .astype(np.float32) * 0.1),
                    "tokens": tokens[:, :seq - p]}
        return {"tokens": tokens}

    return next_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 (data x model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)

    mesh = None
    data_axes: tuple[str, ...] = ()
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)] if len(shape) <= 2 \
            else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)
        data_axes = data_axes_of(mesh)

    params = common.build_params(transformer.param_specs(cfg), key)
    opt_state = opt_init(cfg.optimizer, params)
    step_fn = make_train_step(cfg, mesh=mesh, data_axes=data_axes,
                              base_lr=args.lr, total_steps=args.steps,
                              warmup=min(100, args.steps // 10 + 1),
                              microbatch=1 if args.smoke else None)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.specs import param_pspecs
        pspec = param_pspecs(cfg, mesh, data_axes)
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspec,
            is_leaf=lambda x: isinstance(x, P)))
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3)
        latest = ckpt.latest_step()
        if latest is not None:
            tree = ckpt.restore({"params": params, "opt": opt_state,
                                 "meta": {"step": 0}})
            params, opt_state = tree["params"], tree["opt"]
            start_step = int(tree["meta"]["step"]) + 1
            print(f"[resume] from step {latest} -> starting at {start_step}")

    stop = {"now": False}

    def on_sigterm(signum, frame):
        print("[sigterm] checkpointing and exiting...", flush=True)
        stop["now"] = True

    signal.signal(signal.SIGTERM, on_sigterm)
    signal.signal(signal.SIGINT, on_sigterm)

    next_batch = make_batch_fn(cfg, args.batch, args.seq, args.seed)
    t0 = time.time()
    for step in range(start_step, args.steps):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             next_batch(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            dt = time.time() - t0
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f} "
                  f"skipped {int(m['skipped'])} ({dt:.1f}s)", flush=True)
        if ckpt and (step % args.ckpt_every == 0 or stop["now"]
                     or step == args.steps - 1):
            ckpt.save(step, {"params": params, "opt": opt_state,
                             "meta": {"step": step}})
        if stop["now"]:
            break
    if ckpt:
        ckpt.wait()
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
