"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective analysis.

The two ``os.environ`` lines below MUST stay the first statements: jax locks
the device count at first init, and the dry-run needs 512 placeholder CPU
devices to build the (2, 16, 16) mesh.  Nothing else in the repo sets this
flag (smoke tests and benches see the single real device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
        --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, runnable_shapes


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    """Lower + compile one cell; return its dry-run record."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    from jax.sharding import NamedSharding, PartitionSpec
    in_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cell.in_shardings,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=in_sh,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    n_chips = mesh.size
    roof = rl.analyze(compiled, n_chips=n_chips,
                      model_flops=rl.model_flops_for(cfg, shape))
    mem_row = {
        "argument": getattr(mem, "argument_size_in_bytes", None),
        "output": getattr(mem, "output_size_in_bytes", None),
        "temp": getattr(mem, "temp_size_in_bytes", None),
    }
    # TPU backends report a true peak; the CPU placeholder backend does not,
    # so fall back to the live-set upper bound argument + output + temp.
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None:
        parts = [v for v in mem_row.values() if v is not None]
        peak = sum(parts) if parts else None
    mem_row["peak"] = peak
    rec = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "bytes_per_device": mem_row,
        **roof.table_row(),
    }
    if verbose:
        peak = rec["bytes_per_device"]["peak"]
        print(f"[ok] {arch:24s} {shape:12s} mesh={rec['mesh']:9s} "
              f"peak={0 if peak is None else peak / 2**30:.2f}GiB "
              f"flops/dev={roof.flops:.3e} "
              f"compute={roof.compute_s*1e3:.1f}ms "
              f"memory={roof.memory_s*1e3:.1f}ms "
              f"coll={roof.collective_s*1e3:.1f}ms "
              f"-> {roof.bottleneck} useful={roof.useful_ratio:.2f}",
              flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 multi-pod mesh (default: 16x16 single pod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    records, failures = [], []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for multi_pod in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = [args.shape] if args.shape else runnable_shapes(cfg)
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi_pod=multi_pod)
                except Exception as e:                       # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": f"FAIL: {type(e).__name__}: {e}"}
                    print(f"[FAIL] {arch} {shape} multi_pod={multi_pod}: "
                          f"{e}\n{traceback.format_exc()}", flush=True)
                    failures.append(rec)
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    print(f"\n{len(records) - len(failures)}/{len(records)} cells passed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
