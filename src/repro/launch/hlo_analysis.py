"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned program (layers, microbatches, attention chunks — i.e. every model
here) is undercounted by the trip count.  This module re-derives the three
roofline inputs from the optimized HLO text with loops multiplied out:

  * FLOPs       — ``dot`` ops: 2 * prod(output dims) * prod(contracting
                  dims); elementwise/fusion ops approximated at 1 flop per
                  output element (matmul-dominated programs; documented);
  * HBM bytes   — every non-view op writes its result once; operand reads
                  are DEDUPLICATED per computation (a tensor consumed by
                  five sibling fusions counts once: XLA:CPU fuses far finer
                  than TPU, and counting each small fusion's re-read would
                  charge the TPU roofline for CPU fusion granularity —
                  measured 10x overcount on the MoE train cell).  Views
                  (bitcast/get-tuple-element/tuple) are free;
  * collective bytes — all-reduce counts 2x its tensor (ring reduce-scatter
                  + all-gather), all-gather / reduce-scatter / all-to-all /
                  collective-permute 1x, each multiplied by enclosing loop
                  trip counts.

Trip counts come from the ``known_trip_count`` backend_config XLA:CPU
attaches to while ops (verified present for all lax.scan loops; dynamic
``lax.while_loop``s without it count once and are flagged in
``warnings``).  Validated against cost_analysis on loop-free programs in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))")

COLLECTIVES = {"all-reduce": 2, "all-gather": 1, "reduce-scatter": 1,
               "all-to-all": 1, "collective-permute": 1,
               "ragged-all-to-all": 1}

# ops whose operand/result bytes count as memory traffic
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "custom-call", "copy", "reduce",
    "reduce-window", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "sort", "broadcast", "transpose", "reshape",
    "concatenate", "pad", "select-and-scatter", "slice", "reverse",
    "iota", "rng", "cholesky", "triangular-solve", "select", "compare",
    "add", "multiply", "subtract", "divide", "exponential", "tanh",
    "convert", "clamp", "maximum", "minimum", "map",
} | set(COLLECTIVES) | {k + "-start" for k in COLLECTIVES}

_VIEW_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "partition-id", "replica-id",
             "opt-barrier", "bitcast-convert", "all-reduce-done",
             "all-gather-done", "collective-permute-done", "copy-start",
             "copy-done", "send", "recv", "send-done", "recv-done",
             "domain", "custom-call-start", "custom-call-done"}


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _tensor_elems(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    rest: str          # everything after the opening paren of operands


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]
    ops: list[Op]
    types: dict[str, str]          # every %name -> result type


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict | None = None
    warnings: list | None = None


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR_RE.match(line)
        if m and ("->" in line):
            params = {k: v for k, v in _PARAM_RE.findall(m.group(2))}
            cur = Computation(m.group(1), params, [], dict(params))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, rtype, opcode, rest = om.groups()
            cur.ops.append(Op(name, rtype, opcode, rest))
            cur.types[name] = rtype
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _tensor_elems(op.rtype)
    m = re.search(r"rhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _OPERAND_RE.findall(op.rest.split("),")[0])
    contract = 1
    if m and len(operands) >= 2:
        rhs_t = comp.types.get(operands[1], "")
        sm = _SHAPE_RE.search(rhs_t)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for i in m.group(1).split(","):
                if i and int(i) < len(dims):
                    contract *= dims[int(i)]
    return 2.0 * out_elems * contract


def _operand_bytes(op: Op, comp: Computation) -> int:
    # operands are listed before the closing paren of the op call
    arg_str = op.rest.split("),")[0]
    total = 0
    for nm in _OPERAND_RE.findall(arg_str):
        t = comp.types.get(nm)
        if t:
            total += _tensor_bytes(t)
    return total


def analyze_text(text: str) -> CostTotals:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.rstrip())
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].ops))

    totals = CostTotals(coll_by_op={}, warnings=[])
    seen_stack: list[str] = []

    def fusion_flops(cname: str) -> float:
        c = comps.get(cname)
        if c is None:
            return 0.0
        f = 0.0
        for op in c.ops:
            if op.opcode == "dot":
                f += _dot_flops(op, c)
            elif op.opcode == "fusion":
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    f += fusion_flops(cm.group(1))
            elif op.opcode not in _VIEW_OPS:
                f += _tensor_elems(op.rtype)
        return f

    def walk(cname: str, mult: float) -> None:
        c = comps.get(cname)
        if c is None or cname in seen_stack:
            return
        seen_stack.append(cname)
        read_names: set[str] = set()          # dedup operand reads

        def note_reads(op: Op) -> None:
            arg_str = op.rest.split("),")[0]
            for nm in _OPERAND_RE.findall(arg_str):
                read_names.add(nm)

        for op in c.ops:
            code = op.opcode
            if code == "while":
                tm = _TRIP_RE.search(op.rest)
                trips = int(tm.group(1)) if tm else 1
                if tm is None:
                    totals.warnings.append(
                        f"while {op.name}: unknown trip count, counted once")
                bm = _BODY_RE.search(op.rest)
                cm = _COND_RE.search(op.rest)
                if bm:
                    walk(bm.group(1), mult * trips)
                if cm:
                    walk(cm.group(1), mult * trips)
                continue
            if code == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    for br in _OPERAND_RE.findall(bm.group(1)):
                        walk(br, mult)       # upper bound: all branches
                continue
            if code in ("call", "async-start"):
                # older XLA:CPU spells the callee ``to_apply=`` (e.g. its
                # parallel-task wrapper around the whole entry)
                cm = (_CALLS_RE.search(op.rest) or _BODY_RE.search(op.rest)
                      or _TO_APPLY_RE.search(op.rest))
                if cm:
                    walk(cm.group(1), mult)
                continue
            base = code.removesuffix("-start")
            if base in COLLECTIVES:
                nbytes = _tensor_bytes(op.rtype) * COLLECTIVES[base] * mult
                totals.coll_bytes += nbytes
                totals.coll_by_op[base] = (
                    totals.coll_by_op.get(base, 0) + nbytes)
                totals.bytes += mult * _tensor_bytes(op.rtype)
                note_reads(op)
                continue
            if code == "dot":
                f = _dot_flops(op, c) * mult
                totals.flops += f
                totals.dot_flops += f
                totals.bytes += mult * _tensor_bytes(op.rtype)
                note_reads(op)
                continue
            if code == "fusion":
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    totals.flops += fusion_flops(cm.group(1)) * mult
                totals.bytes += mult * _tensor_bytes(op.rtype)
                note_reads(op)
                continue
            if code in _VIEW_OPS:
                continue
            # everything else (elementwise, copies, slices, reduces, ...)
            if code in _TRAFFIC_OPS:
                totals.flops += _tensor_elems(op.rtype) * mult
            totals.bytes += mult * _tensor_bytes(op.rtype)
            note_reads(op)

        # deduplicated operand reads for this computation visit
        for nm in read_names:
            t = c.types.get(nm)
            if t:
                totals.bytes += mult * _tensor_bytes(t)
        seen_stack.pop()

    walk(entry, 1.0)
    return totals
