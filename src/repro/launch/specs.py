"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch x shape) cell.

``input_specs`` returns the abstract inputs each cell's step function is
lowered with — weak-type-correct, shardable, zero allocation.  The sharding
rules (DESIGN.md §7):

  batch        -> data axes ("pod","data")
  params       -> logical-axis resolver (model TP/EP; FSDP over data for the
                  >=27B archs)
  KV caches    -> batch over data; kv_heads (else head_dim) over model;
                  long_500k (batch=1) full-attention caches shard the
                  SEQUENCE over the data axes instead (flash-decode merge)
  optimizer    -> mirrors params (factored dims dropped for adafactor)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell, SHAPES
from repro.models import common, transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dp(data_axes):
    return data_axes if len(data_axes) > 1 else data_axes[0]


def _divisible(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, cell: ShapeCell, *,
                act_dtype=jnp.bfloat16) -> dict:
    """Abstract train/prefill batch for one cell."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.enc_dec:
        return {"frames": _sds((b, s, cfg.d_model), act_dtype),
                "dec_tokens": _sds((b, cfg.decoder_len), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.n_patches
        return {"patches": _sds((b, p, cfg.d_model), act_dtype),
                "tokens": _sds((b, s - p), jnp.int32)}
    return {"tokens": _sds((b, s), jnp.int32)}


def batch_pspecs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                 data_axes: tuple[str, ...]) -> dict:
    dp = _dp(data_axes)
    nd = math.prod(mesh.shape[a] for a in data_axes)
    bp = dp if cell.global_batch % nd == 0 else None
    if cfg.enc_dec:
        return {"frames": P(bp, None, None), "dec_tokens": P(bp, None)}
    if cfg.family == "vlm":
        return {"patches": P(bp, None, None), "tokens": P(bp, None)}
    return {"tokens": P(bp, None)}


# ---------------------------------------------------------------------------
# Param / optimizer specs
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return common.params_shape_tree(transformer.param_specs(cfg), dtype)


def param_pspecs(cfg: ModelConfig, mesh: Mesh,
                 data_axes: tuple[str, ...]):
    specs = transformer.param_specs(cfg)
    axes_t = common.axes_tree(specs)
    shapes_t = param_shapes(cfg)
    return common.resolve_pspecs(axes_t, shapes_t, mesh, fsdp=cfg.fsdp,
                                 data_axes=data_axes)


def opt_specs(cfg: ModelConfig, mesh: Mesh, data_axes: tuple[str, ...]):
    pp = param_pspecs(cfg, mesh, data_axes)
    shapes = param_shapes(cfg)
    return (opt_lib.opt_state_shapes(cfg.optimizer, shapes),
            opt_lib.opt_state_specs(cfg.optimizer, pp, shapes))


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len, dtype))


def kv_shard_axes(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                  data_axes: tuple[str, ...]) -> tuple | None:
    """Axes over which full-attention decode caches shard their SEQUENCE.

    long_500k (batch=1): the data axes (batch can't shard).  Other decode
    cells where kv_heads doesn't divide the model axis: the MODEL axis —
    head_dim-sharding makes GSPMD all-gather the whole cache every step
    ("involuntary full rematerialization": 90 GB/step on command-r, 385
    GB/step on nemotron), and full replication blows HBM (173 GiB/dev on
    nemotron); seq-sharding + the shard_map flash-decode merge fixes both
    (EXPERIMENTS.md §Perf iteration 2)."""
    if cell.kind != "decode":
        return None
    nd = math.prod(mesh.shape[a] for a in data_axes)
    if cell.global_batch % nd != 0:
        return data_axes                      # long_500k
    if cfg.enc_dec or cfg.family == "ssm":
        return None
    if not _divisible(cfg.n_kv_heads, mesh, "model") \
            and cell.seq_len % mesh.shape["model"] == 0:
        return ("model",)
    return None


def cache_pspecs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                 data_axes: tuple[str, ...], *,
                 kv_shard: tuple | None = None):
    """PartitionSpec tree matching init_cache's structure."""
    dp = _dp(data_axes)
    nd = math.prod(mesh.shape[a] for a in data_axes)
    bp = dp if cell.global_batch % nd == 0 else None
    mdl = "model"

    def kv_spec(kvh: int, hd: int, full_attn: bool) -> P:
        # (run, B, S, KVH, hd); see kv_shard_axes for the sharding story.
        h_ax = mdl if _divisible(kvh, mesh, mdl) else None
        if kv_shard and full_attn:
            s_ax = kv_shard if len(kv_shard) > 1 else kv_shard[0]
            if "model" in kv_shard:
                return P(None, bp, s_ax, None, None)
            return P(None, None, s_ax, h_ax, None)
        return P(None, bp, None, h_ax, None)

    if cfg.enc_dec:
        sp = kv_spec(cfg.n_kv_heads, cfg.head_dim, False)
        return [dict(k=sp, v=sp, xk=sp, xv=sp)]
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        h_ax = mdl if _divisible(h, mesh, mdl) else None
        return [dict(s=P(None, bp, h_ax, None, None),
                     x_tm=P(None, bp, None), x_cm=P(None, bp, None))]
    out = []
    for seg in transformer.segments(cfg):
        full = seg.kind == "full"
        c = dict(k=kv_spec(cfg.n_kv_heads, cfg.head_dim, full),
                 v=kv_spec(cfg.n_kv_heads, cfg.head_dim, full))
        if cfg.family == "hybrid":
            d_ax = mdl if _divisible(cfg.q_dim, mesh, mdl) else None
            c.update(m_h=P(None, bp, d_ax, None),
                     m_conv=P(None, bp, None, d_ax))
        out.append(c)
    return out


# ---------------------------------------------------------------------------
# Cell assembly: everything dryrun needs to lower one (arch x shape x mesh)
# ---------------------------------------------------------------------------


class Cell(NamedTuple):
    arch: str
    shape: str
    kind: str                       # train | prefill | decode
    fn: Callable                    # the step function to lower
    args: tuple                     # abstract args
    in_shardings: tuple
    donate: tuple[int, ...] = ()


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               *, act_dtype=jnp.bfloat16) -> Cell:
    cell = SHAPES[shape_name]
    from repro.launch.mesh import data_axes_of
    data_axes = data_axes_of(mesh)
    dp = _dp(data_axes)

    p_shapes = param_shapes(cfg)
    p_specs = param_pspecs(cfg, mesh, data_axes)

    if cell.kind == "train":
        o_shapes, o_specs = opt_specs(cfg, mesh, data_axes)
        b_shapes = batch_specs(cfg, cell, act_dtype=act_dtype)
        b_specs = batch_pspecs(cfg, cell, mesh, data_axes)
        fn = step_lib.make_train_step(cfg, mesh=mesh, data_axes=data_axes)
        return Cell(cfg.name, shape_name, "train", fn,
                    (p_shapes, o_shapes, b_shapes),
                    (p_specs, o_specs, b_specs), donate=(0, 1))

    if cell.kind == "prefill":
        b_shapes = batch_specs(cfg, cell, act_dtype=act_dtype)
        b_specs = batch_pspecs(cfg, cell, mesh, data_axes)
        c_shapes = cache_shapes(cfg, cell.global_batch, cell.seq_len)
        c_specs = cache_pspecs(cfg, cell, mesh, data_axes)
        fn = step_lib.make_prefill_step(cfg, mesh=mesh, data_axes=data_axes)
        return Cell(cfg.name, shape_name, "prefill", fn,
                    (p_shapes, b_shapes, c_shapes),
                    (p_specs, b_specs, c_specs), donate=(2,))

    # decode: one new token against a cache of seq_len
    nd = math.prod(mesh.shape[a] for a in data_axes)
    b = cell.global_batch
    kvs = kv_shard_axes(cfg, cell, mesh, data_axes)
    c_shapes = cache_shapes(cfg, b, cell.seq_len)
    tok = _sds((b, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    c_specs = cache_pspecs(cfg, cell, mesh, data_axes, kv_shard=kvs)
    bp = dp if b % nd == 0 else None
    fn = step_lib.make_serve_step(cfg, mesh=mesh, data_axes=data_axes,
                                  kv_shard=kvs)
    return Cell(cfg.name, shape_name, "decode", fn,
                (p_shapes, tok, pos, c_shapes),
                (p_specs, P(bp, None), P(), c_specs), donate=(3,))


def runnable_shapes(cfg: ModelConfig) -> list[str]:
    """The cells this arch runs (long_500k only for sub-quadratic; no decode
    cells for encoder-only archs — all 10 assigned archs decode)."""
    return [s for s in SHAPES if cfg.runs_shape(s)]
