"""repro.analysis — repo-invariant static checkers + runtime sanitizer.

Three AST-based checkers (stdlib ``ast`` only, no third-party deps)
machine-check invariants that used to live as prose in DESIGN.md:

- :mod:`repro.analysis.locks` — lock-discipline: every access to a
  field annotated ``# guarded by: <lock>`` happens under
  ``with self.<lock>:`` or inside a ``# caller holds <lock>`` helper
  whose call sites are themselves verified.
- :mod:`repro.analysis.syncs` — host-sync tracer: implicit
  device->host transfers (``float()``, ``np.asarray``, ``.item()``,
  ...) inside jitted functions and ``lax`` loop bodies must carry an
  explicit ``# sync`` annotation.
- :mod:`repro.analysis.contracts` — kernel/dispatch contracts: every
  Pallas kernel entry has a same-signature oracle in ``kernels/ref.py``
  and every jitted function that reaches the ``ops.*`` mode dispatch is
  registered via ``register_dispatch_cache``.

Run the suite with ``python -m repro.analysis src/`` (see
:mod:`repro.analysis.cli`).  ``REPRO_SANITIZE=1`` additionally arms the
runtime lock assertions in :mod:`repro.analysis.sanitize`.
"""
from __future__ import annotations

from .common import Finding, Project
from .cli import run_analysis

__all__ = ["Finding", "Project", "run_analysis"]
