"""Lock-discipline checker.

A field annotated ``# guarded by: <lock>`` at its ``self.<field> = ...``
declaration may only be read or written (a) lexically inside
``with self.<lock>:``, or (b) inside a method annotated
``# caller holds <lock>`` — in which case every *call site* of that
method must itself hold the lock (or be another caller-holds method
for the same lock).

Scope and limits (documented, deliberate):

- Only ``self.<field>`` accesses inside the declaring class are
  checked; cross-object reads (``other.field``) are out of static
  scope — the ``REPRO_SANITIZE=1`` runtime wrappers in
  :mod:`repro.analysis.sanitize` cover mutations at runtime.
- ``__init__`` is exempt: the object is not yet shared.
- Nested functions and lambdas run later, possibly off-lock, so they
  start with an *empty* held-set even when defined under ``with``.
"""
from __future__ import annotations

import ast

from .common import Finding, Project, SourceFile

__all__ = ["check", "class_guarded_fields"]


def _self_attr(node: ast.expr) -> str | None:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def class_guarded_fields(sf: SourceFile,
                         cls: ast.ClassDef) -> dict[str, str]:
    """``field -> lock`` map from ``# guarded by:`` annotations on
    ``self.<field> = ...`` assignments anywhere in the class."""
    guarded: dict[str, str] = {}
    for node in ast.walk(cls):
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        for t in targets:
            fieldname = _self_attr(t)
            if fieldname is None:
                continue
            lock = sf.guarded_by(node.lineno)
            if lock:
                guarded[fieldname] = lock
    return guarded


class _MethodWalker(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, clsname: str,
                 guarded: dict[str, str], holds: dict[str, str],
                 findings: list[Finding]):
        self.sf = sf
        self.clsname = clsname
        self.guarded = guarded
        self.holds = holds
        self.findings = findings
        self.held: frozenset[str] = frozenset()

    # -- scoping ------------------------------------------------------
    def visit_With(self, node: ast.With):
        for item in node.items:
            self.visit(item.context_expr)
        added = {a for item in node.items
                 if (a := _self_attr(item.context_expr))}
        old = self.held
        self.held = old | added
        for stmt in node.body:
            self.visit(stmt)
        self.held = old

    def _deferred(self, node):
        """Nested defs/lambdas execute later: no locks assumed held."""
        old = self.held
        self.held = frozenset()
        self.generic_visit(node)
        self.held = old

    visit_FunctionDef = _deferred
    visit_AsyncFunctionDef = _deferred
    visit_Lambda = _deferred

    # -- checks -------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        attr = _self_attr(node)
        if attr is not None and attr in self.guarded:
            lock = self.guarded[attr]
            if lock not in self.held:
                self.findings.append(Finding(
                    self.sf.path, node.lineno, "LOCK001",
                    f"{self.clsname}.{attr} is guarded by "
                    f"self.{lock} but accessed without holding it"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        attr = _self_attr(node.func)
        if attr is not None and attr in self.holds:
            lock = self.holds[attr]
            if lock not in self.held:
                self.findings.append(Finding(
                    self.sf.path, node.lineno, "LOCK002",
                    f"{self.clsname}.{attr} requires the caller to "
                    f"hold self.{lock} (see its '# caller holds' "
                    f"annotation) but is called without it"))
        self.generic_visit(node)


def _check_class(sf: SourceFile, cls: ast.ClassDef,
                 findings: list[Finding]) -> None:
    guarded = class_guarded_fields(sf, cls)
    holds = {m.name: lock for m in cls.body
             if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
             and (lock := sf.caller_holds(m))}
    if not guarded and not holds:
        return
    for m in cls.body:
        if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if m.name == "__init__":
            continue
        w = _MethodWalker(sf, cls.name, guarded, holds, findings)
        if m.name in holds:
            w.held = frozenset({holds[m.name]})
        for stmt in m.body:
            w.visit(stmt)


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                _check_class(sf, node, findings)
    return findings
