"""Kernel/dispatch contract checkers.

**Kernel-oracle contract** (KERN00x): every Pallas kernel module under
``repro/kernels/`` (everything except ``__init__``/``ops``/``ref``)
must pair each public entry — a top-level jit-decorated function —
with a same-signature oracle in ``kernels/ref.py``.  The oracle is
``<entry>_ref`` by default; a trailing ``# oracle: <name>`` comment on
the ``def`` line overrides.  Signatures match when the parameter-name
sets are equal after stripping tuning-only parameters (``interpret``
and anything starting with ``tile_``).

**Dispatch-registry contract** (DISP001): every module-level jitted
function whose body (transitively, over an AST-derived call graph)
reaches one of the ``ops.*`` mode-dispatch wrappers — the top-level
functions in ``kernels/ops.py`` that consult ``_use_pallas()`` — must
be registered via ``register_dispatch_cache`` so ``ops.set_mode``
can clear its trace cache.  The call graph is conservative: a
``obj.m(...)`` call edges to *every* repo class method named ``m``
(minus a small builtin-collision denylist), so reachability
over-approximates — exactly what you want for a cache-invalidation
invariant.
"""
from __future__ import annotations

import ast

from .common import (Finding, Project, SourceFile, decorator_is_jit,
                     top_level_functions)

__all__ = ["check", "check_oracles", "check_dispatch"]

_TUNING_PARAMS = {"interpret"}

# obj.m() edges skip method names that collide with ubiquitous
# builtin/stdlib attributes; none of the repo's dispatch-reaching
# methods (metric.block_lb / distances / panel_topk / ...) are here.
_COMMON_ATTRS = {
    "append", "extend", "items", "keys", "values", "get", "pop",
    "update", "setdefault", "copy", "sort", "split", "join", "format",
    "add", "discard", "remove", "index", "count", "startswith",
    "endswith", "astype", "reshape", "result", "submit", "put",
    "acquire", "release", "wait", "set", "clear", "close", "flush",
    "write", "read",
}


def _params(fn: ast.FunctionDef) -> set[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    return {n for n in names
            if n not in _TUNING_PARAMS and not n.startswith("tile_")}


def check_oracles(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    kernel_files = [
        f for f in project.files
        if (p := f.module.split("."))[-1] not in ("ops", "ref")
        and len(p) >= 2 and p[-2] == "kernels"]
    if not kernel_files:
        return findings
    ref = project.find_module("kernels.ref")
    ref_fns = {fn.name: fn for fn in
               top_level_functions(ref.tree)} if ref else {}

    for sf in kernel_files:
        for fn in top_level_functions(sf.tree):
            if fn.name.startswith("_"):
                continue
            if not any(decorator_is_jit(d) for d in fn.decorator_list):
                continue
            oracle = sf.oracle_override(fn.lineno) or f"{fn.name}_ref"
            if ref is None:
                findings.append(Finding(
                    sf.path, fn.lineno, "KERN002",
                    f"kernel entry {fn.name} needs an oracle but "
                    f"kernels/ref.py is not in the analysis set"))
                continue
            target = ref_fns.get(oracle)
            if target is None:
                findings.append(Finding(
                    sf.path, fn.lineno, "KERN001",
                    f"kernel entry {fn.name} has no oracle "
                    f"{oracle}() in kernels/ref.py (add one, or map "
                    f"it with '# oracle: <name>')"))
            elif _params(target) != _params(fn):
                findings.append(Finding(
                    sf.path, fn.lineno, "KERN003",
                    f"kernel entry {fn.name}{sorted(_params(fn))} and "
                    f"oracle {oracle}{sorted(_params(target))} "
                    f"disagree on parameter names"))
    return findings


# ---------------------------------------------------------------------
# dispatch-registry contract


def _import_map(tree: ast.Module) -> dict[str, str]:
    """alias -> dotted target (module, or module.attr for from-imports)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


class _Graph:
    """Call graph over (module, qualname) nodes."""

    def __init__(self):
        self.edges: dict[tuple[str, str], set[tuple[str, str]]] = {}
        self.methods_by_name: dict[str, list[tuple[str, str]]] = {}

    def edge(self, src, dst):
        self.edges.setdefault(src, set()).add(dst)

    def reachable(self, start, targets: set) -> bool:
        seen, todo = {start}, [start]
        while todo:
            cur = todo.pop()
            if cur in targets:
                return True
            for nxt in self.edges.get(cur, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    todo.append(nxt)
        return False


def _resolve_module(project: Project, dotted: str) -> str | None:
    """Map an imported dotted name to a project module name."""
    sf = project.find_module(dotted)
    return sf.module if sf else None


def _collect_calls(project: Project, graph: _Graph, sf: SourceFile,
                   src: tuple[str, str], fn: ast.AST,
                   imports: dict[str, str],
                   local_toplevel: set[str]) -> None:
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            if f.id in local_toplevel:
                graph.edge(src, (sf.module, f.id))
            elif f.id in imports:
                dotted = imports[f.id]
                mod, _, name = dotted.rpartition(".")
                m = _resolve_module(project, mod)
                if m:
                    graph.edge(src, (m, name))
        elif isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name) and \
                    f.value.id in imports:
                m = _resolve_module(project, imports[f.value.id])
                if m:
                    graph.edge(src, (m, f.attr))
                    continue
            # obj.m(...) — conservative: edge to every repo method m
            if f.attr not in _COMMON_ATTRS:
                for key in graph.methods_by_name.get(f.attr, ()):
                    graph.edge(src, key)


def check_dispatch(project: Project) -> list[Finding]:
    ops = project.find_module("kernels.ops")
    if ops is None:
        return []

    dispatch_targets = set()
    for fn in top_level_functions(ops.tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and (
                    (isinstance(node.func, ast.Name)
                     and node.func.id == "_use_pallas")
                    or (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "_use_pallas")):
                dispatch_targets.add((ops.module, fn.name))
                break
    if not dispatch_targets:
        return []

    graph = _Graph()
    # pass 1: index every class method so obj.m() edges can resolve
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                for m in node.body:
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        graph.methods_by_name.setdefault(
                            m.name, []).append(
                            (sf.module, f"{node.name}.{m.name}"))

    jitted: list[tuple[SourceFile, ast.FunctionDef]] = []
    registered: set[tuple[str, str]] = set()

    # pass 2: edges, jitted set, registrations
    for sf in project.files:
        imports = _import_map(sf.tree)
        local = {fn.name for fn in top_level_functions(sf.tree)}
        for fn in top_level_functions(sf.tree):
            _collect_calls(project, graph, sf, (sf.module, fn.name),
                           fn, imports, local)
            if any(decorator_is_jit(d) for d in fn.decorator_list):
                jitted.append((sf, fn))
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                for m in node.body:
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        _collect_calls(
                            project, graph, sf,
                            (sf.module, f"{node.name}.{m.name}"),
                            m, imports, local)
            elif isinstance(node, ast.Call):
                f = node.func
                is_reg = (isinstance(f, ast.Name)
                          and f.id == "register_dispatch_cache") or \
                         (isinstance(f, ast.Attribute)
                          and f.attr == "register_dispatch_cache")
                if is_reg and node.args and \
                        isinstance(node.args[0], ast.Name):
                    registered.add((sf.module, node.args[0].id))

    findings = []
    for sf, fn in jitted:
        key = (sf.module, fn.name)
        if key in registered:
            continue
        if graph.reachable(key, dispatch_targets):
            findings.append(Finding(
                sf.path, fn.lineno, "DISP001",
                f"jitted function {fn.name} reaches the ops.* kernel "
                f"dispatch but is not registered via "
                f"register_dispatch_cache — ops.set_mode cannot "
                f"clear its trace cache"))
    return findings


def check(project: Project) -> list[Finding]:
    return check_oracles(project) + check_dispatch(project)
