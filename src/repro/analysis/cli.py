"""CLI driver: ``python -m repro.analysis [paths...]``.

Collects ``.py`` files under the given paths (default ``src``), runs
the three checkers, and prints findings in ``text`` or ``github``
(workflow-annotation) format.  Exit code 1 iff there are findings —
this is the CI lint gate.
"""
from __future__ import annotations

import argparse
import os
import sys

from . import contracts, locks, syncs
from .common import Finding, Project, SourceFile

_CHECKS = {
    "locks": locks.check,
    "syncs": syncs.check,
    "contracts": contracts.check,
}


def collect_py_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".")
                             and d != "__pycache__")
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return out


def load_project(paths: list[str]) -> tuple[Project, list[Finding]]:
    files, errors = [], []
    for path in collect_py_files(paths):
        try:
            with open(path, encoding="utf-8") as fh:
                files.append(SourceFile(path=path, source=fh.read()))
        except SyntaxError as e:
            errors.append(Finding(path, e.lineno or 1, "PARSE001",
                                  f"cannot parse: {e.msg}"))
    return Project(files), errors


def run_analysis(project: Project,
                 checks: tuple[str, ...] = ("locks", "syncs",
                                            "contracts"),
                 ) -> list[Finding]:
    findings: list[Finding] = []
    for name in checks:
        findings.extend(_CHECKS[name](project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant linters: lock discipline, "
                    "host-sync tracing, kernel/dispatch contracts")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze "
                         "(default: src)")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text")
    ap.add_argument("--checks", default="locks,syncs,contracts",
                    help="comma-separated subset of: "
                         + ",".join(_CHECKS))
    args = ap.parse_args(argv)

    checks = tuple(c for c in args.checks.split(",") if c)
    unknown = [c for c in checks if c not in _CHECKS]
    if unknown:
        ap.error(f"unknown checks: {unknown}")

    project, findings = load_project(args.paths)
    findings += run_analysis(project, checks)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    for f in findings:
        print(f.github() if args.format == "github" else f.text())
    n = len(project.files)
    print(f"repro.analysis: {len(findings)} finding(s) in {n} "
          f"file(s) [{','.join(checks)}]", file=sys.stderr)
    return 1 if findings else 0
