"""Runtime lock-discipline sanitizer (``REPRO_SANITIZE=1``).

The static checker in :mod:`repro.analysis.locks` proves lexical
discipline; this module catches what statics cannot — a guarded field
mutated through an alias, from a thread the checker never saw, or via
a path added after annotation.  Two pieces:

- :func:`create_lock` — drop-in for ``threading.Lock()``.  Returns a
  plain lock when the sanitizer is off; an :class:`InstrumentedLock`
  (owner-tracking, context-manager compatible) when on.
- :func:`guarded` — class decorator.  When the sanitizer is on it
  re-parses the class's own ``# guarded by:`` source annotations (the
  same grammar the static checker reads — one source of truth) and
  wraps ``__setattr__`` to assert the mapped lock is held by the
  mutating thread.  Assignments during ``__init__`` are exempt, same
  as the static rule.  When off, the decorator returns the class
  unchanged: zero overhead, no source parsing.

Benchmarks must never run instrumented: ``benchmarks/run.py`` asserts
:func:`enabled` is false.
"""
from __future__ import annotations

import ast
import inspect
import os
import textwrap
import threading

__all__ = ["enabled", "create_lock", "guarded", "InstrumentedLock",
           "SanitizeError"]

_ENV = "REPRO_SANITIZE"


def enabled() -> bool:
    return os.environ.get(_ENV, "0") not in ("", "0")


class SanitizeError(AssertionError):
    """A guarded field was mutated without its lock held."""


class InstrumentedLock:
    """``threading.Lock`` plus owner-thread tracking."""

    def __init__(self):
        self._lock = threading.Lock()
        self._owner: int | None = None

    def acquire(self, *args, **kw) -> bool:
        got = self._lock.acquire(*args, **kw)
        if got:
            self._owner = threading.get_ident()
        return got

    def release(self) -> None:
        self._owner = None
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


def create_lock():
    """Factory for guarded-class locks: instrumented iff sanitizing."""
    return InstrumentedLock() if enabled() else threading.Lock()


def _guarded_map(cls) -> dict[str, str]:
    """``field -> lock`` from the class's ``# guarded by:`` comments,
    parsed with the same grammar as the static checker."""
    from .common import SourceFile
    from .locks import class_guarded_fields
    try:
        src = textwrap.dedent(inspect.getsource(cls))
    except (OSError, TypeError):
        return {}
    sf = SourceFile(path=f"<{cls.__name__}>", source=src)
    node = sf.tree.body[0]
    if not isinstance(node, ast.ClassDef):
        return {}
    return class_guarded_fields(sf, node)


def guarded(cls):
    """Class decorator: assert lock holdership on guarded mutations.

    Subclass-safe: decorate both base and subclass and each layer
    checks its own map, chaining ``__setattr__`` through the MRO.
    ``__init__`` bodies (including ``super().__init__``) are exempt
    via a per-instance construction-depth counter.
    """
    if not enabled():
        return cls
    gmap = _guarded_map(cls)

    orig_init = cls.__init__
    orig_setattr = cls.__setattr__

    def __init__(self, *args, **kw):
        depth = getattr(self, "_sanitize_init_depth", 0)
        object.__setattr__(self, "_sanitize_init_depth", depth + 1)
        try:
            orig_init(self, *args, **kw)
        finally:
            object.__setattr__(self, "_sanitize_init_depth", depth)

    def __setattr__(self, name, value):
        if name in gmap and \
                getattr(self, "_sanitize_init_depth", 1) == 0:
            lock = getattr(self, gmap[name], None)
            if isinstance(lock, InstrumentedLock) and \
                    not lock.held_by_me():
                raise SanitizeError(
                    f"{type(self).__name__}.{name} is guarded by "
                    f"{gmap[name]} but was mutated without holding "
                    f"it (REPRO_SANITIZE=1)")
        orig_setattr(self, name, value)

    cls.__init__ = __init__
    cls.__setattr__ = __setattr__
    return cls
