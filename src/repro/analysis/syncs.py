"""Host-sync tracer.

Two rules:

1. **Traced scopes** (SYNC001): inside a jit-decorated function, a
   function passed to ``lax.scan`` / ``lax.fori_loop`` /
   ``lax.while_loop`` / ``lax.cond`` / ``lax.switch`` / ``lax.map``,
   or anything lexically nested in one, any implicit device->host
   conversion is flagged: ``float()`` / ``int()`` / ``bool()`` on a
   non-literal, ``np.asarray`` / ``np.array`` (plain-numpy aliases
   only — ``jnp`` is fine), ``jax.device_get``, ``.item()``,
   ``.tolist()``.  These either sync or fail at trace time; both are
   bugs the annotation must own.
2. **Sync-traced modules** (SYNC002): a module carrying a
   ``# repro: sync-trace`` directive opts its *entire* body into
   tracing of the explicit conversion APIs (``np.asarray`` /
   ``np.array`` / ``jax.device_get`` / ``.item()`` / ``.tolist()``;
   bare ``float()``/``int()`` are too common on host scalars to flag
   module-wide).  This is how ``core/engine.py`` pins its
   one-sync-per-group claim.

Suppressions: a trailing comment containing the word ``sync``
sanctions a deliberate transfer; a trailing ``# host`` comment asserts
the operand is plain host data (python ints/lists), so no transfer
occurs.
"""
from __future__ import annotations

import ast

from .common import Finding, Project, SourceFile, decorator_is_jit

__all__ = ["check"]

_LAX_BODY_TAKERS = {"scan", "fori_loop", "while_loop", "cond",
                    "switch", "map"}
_NUMPY_MODULES = {"numpy"}
_SCALARIZERS = {"float", "int", "bool"}
_METHOD_SYNCS = {"item", "tolist"}


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    """local alias -> imported module name (``np`` -> ``numpy``)."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = \
                    f"{node.module}.{a.name}"
    return aliases


def _traced_roots(sf: SourceFile) -> list[ast.AST]:
    """Function nodes whose bodies run under a jax trace: jit-decorated
    defs, defs passed (by name or inline lambda) to lax loop/branch
    combinators, and ``name = jax.jit(fn)`` targets."""
    defs_by_name: dict[str, list[ast.AST]] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, []).append(node)

    roots: list[ast.AST] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(decorator_is_jit(d) for d in node.decorator_list):
                roots.append(node)
        elif isinstance(node, ast.Call):
            fn = node.func
            is_lax = (isinstance(fn, ast.Attribute)
                      and fn.attr in _LAX_BODY_TAKERS
                      and isinstance(fn.value, (ast.Name, ast.Attribute))
                      and (fn.value.id if isinstance(fn.value, ast.Name)
                           else fn.value.attr) in ("lax", "jax"))
            is_jit_call = (isinstance(fn, ast.Attribute)
                           and fn.attr == "jit") or \
                          (isinstance(fn, ast.Name) and fn.id == "jit")
            if not (is_lax or is_jit_call):
                continue
            for arg in list(node.args) + [kw.value for kw in
                                          node.keywords]:
                if isinstance(arg, ast.Lambda):
                    roots.append(arg)
                elif isinstance(arg, ast.Name):
                    roots.extend(defs_by_name.get(arg.id, []))
    return roots


class _SyncScan(ast.NodeVisitor):
    """Collects conversion-call sites; caller filters by scope/rule."""

    def __init__(self, sf: SourceFile, aliases: dict[str, str],
                 explicit_only: bool):
        self.sf = sf
        self.aliases = aliases
        self.explicit_only = explicit_only
        self.hits: list[tuple[int, str]] = []

    def visit_Call(self, node: ast.Call):
        fn = node.func
        line = node.lineno
        if isinstance(fn, ast.Name) and fn.id in _SCALARIZERS \
                and not self.explicit_only:
            if node.args and not isinstance(node.args[0], ast.Constant):
                self.hits.append(
                    (line, f"{fn.id}() on a traced value forces a "
                           f"device->host sync"))
        elif isinstance(fn, ast.Attribute):
            owner = fn.value
            owner_mod = None
            if isinstance(owner, ast.Name):
                owner_mod = self.aliases.get(owner.id)
            if fn.attr in ("asarray", "array") and \
                    owner_mod in _NUMPY_MODULES:
                self.hits.append(
                    (line, f"{owner.id}.{fn.attr}(...) pulls the "
                           f"operand to host"))
            elif fn.attr == "device_get" and owner_mod == "jax":
                self.hits.append((line, "jax.device_get(...) is an "
                                        "explicit device->host sync"))
            elif fn.attr in _METHOD_SYNCS:
                self.hits.append(
                    (line, f".{fn.attr}() on an array syncs it to "
                           f"host"))
        self.generic_visit(node)


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[tuple[str, int]] = set()

    def emit(sf: SourceFile, code: str, hits: list[tuple[int, str]],
             where: str):
        for line, msg in hits:
            if (sf.path, line) in seen:
                continue
            if sf.sync_ok(line) or sf.host_ok(line):
                continue
            seen.add((sf.path, line))
            findings.append(Finding(
                sf.path, line, code,
                f"{msg} {where}; annotate with '# sync' if deliberate "
                f"or '# host' if the operand is host data"))

    for sf in project.files:
        aliases = _import_aliases(sf.tree)
        for root in _traced_roots(sf):
            scan = _SyncScan(sf, aliases, explicit_only=False)
            body = root.body  # Lambda bodies are a bare expression
            for stmt in (body if isinstance(body, list) else [body]):
                scan.visit(stmt)
            emit(sf, "SYNC001", scan.hits,
                 "inside a jit/lax-traced scope")
        if sf.sync_trace_module():
            scan = _SyncScan(sf, aliases, explicit_only=True)
            scan.visit(sf.tree)
            emit(sf, "SYNC002", scan.hits,
                 "in a '# repro: sync-trace' module")
    return findings
