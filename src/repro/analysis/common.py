"""Shared model for the checkers: findings, parsed source files, and
the comment-annotation grammar.

Annotation grammar (all annotations are ordinary ``#`` comments):

- ``# guarded by: <lock>`` — trailing comment on a ``self.<field> = ...``
  assignment inside a class body.  Declares that every later read/write
  of ``self.<field>`` must hold ``self.<lock>``.  ``<lock>`` may be
  written with or without the ``self.`` prefix.
- ``# caller holds <lock>`` — trailing comment on a ``def`` line (or a
  comment line directly above/below it, before the first statement).
  Declares the method relies on its caller to hold the lock; the
  checker then verifies every call site instead.
- ``# ... sync ...`` — any trailing comment containing the word
  ``sync`` sanctions a device->host transfer on that line.
- ``# host`` — trailing comment asserting the converted value is plain
  host data (python ints/lists), not a traced array: not a sync.
- ``# repro: sync-trace`` — module directive (comment anywhere at the
  top level) opting the whole module into host-sync tracing, not just
  its jitted scopes.
- ``# oracle: <name>`` — trailing comment on a kernel entry ``def``
  line naming its oracle in ``kernels/ref.py`` when it is not
  ``<entry>_ref``.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One checker diagnostic, pointing at a file:line."""

    path: str
    line: int
    code: str      # e.g. "LOCK001"
    message: str

    def text(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def github(self) -> str:
        return (f"::error file={self.path},line={self.line},"
                f"title={self.code}::{self.message}")


_GUARDED_RE = re.compile(r"#\s*guarded by:\s*(?:self\.)?(\w+)")
_CALLER_HOLDS_RE = re.compile(r"#\s*caller holds\s+(?:self\.)?(\w+)")
_SYNC_WORD_RE = re.compile(r"#[^#]*\bsync\b")
_HOST_RE = re.compile(r"#\s*host\b")
_ORACLE_RE = re.compile(r"#\s*oracle:\s*(\w+)")
_SYNC_TRACE_DIRECTIVE = re.compile(r"#\s*repro:\s*sync-trace\b")


@dataclass
class SourceFile:
    """A parsed module: source text, AST, and its comment map."""

    path: str           # display path (as given on the CLI)
    source: str
    tree: ast.Module = field(repr=False, default=None)  # type: ignore
    comments: dict[int, str] = field(default_factory=dict)  # line -> text

    def __post_init__(self):
        if self.tree is None:
            self.tree = ast.parse(self.source, filename=self.path)
        try:
            toks = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:
            pass

    # -- annotation lookups -------------------------------------------
    def comment_on(self, line: int) -> str:
        return self.comments.get(line, "")

    def guarded_by(self, line: int) -> str | None:
        m = _GUARDED_RE.search(self.comment_on(line))
        return m.group(1) if m else None

    def caller_holds(self, node: ast.FunctionDef) -> str | None:
        """``# caller holds <lock>`` on the def line or a comment line
        between the decorators and the first body statement."""
        first = node.body[0].lineno if node.body else node.lineno + 1
        for ln in range(node.lineno, first + 1):
            m = _CALLER_HOLDS_RE.search(self.comment_on(ln))
            if m:
                return m.group(1)
        return None

    def sync_ok(self, line: int) -> bool:
        return bool(_SYNC_WORD_RE.search(self.comment_on(line)))

    def host_ok(self, line: int) -> bool:
        return bool(_HOST_RE.search(self.comment_on(line)))

    def oracle_override(self, line: int) -> str | None:
        m = _ORACLE_RE.search(self.comment_on(line))
        return m.group(1) if m else None

    def sync_trace_module(self) -> bool:
        return any(_SYNC_TRACE_DIRECTIVE.search(c)
                   for c in self.comments.values())

    @property
    def module(self) -> str:
        """Dotted module name, rooted at the ``repro`` package when the
        path contains one (``src/repro/core/engine.py`` ->
        ``repro.core.engine``); bare stem otherwise."""
        parts = self.path.replace("\\", "/").split("/")
        stem = [p[:-3] if p.endswith(".py") else p for p in parts]
        if "repro" in stem:
            stem = stem[stem.index("repro"):]
        name = ".".join(stem)
        return name[:-len(".__init__")] if name.endswith(".__init__") \
            else name


class Project:
    """The set of files one analysis run sees (checkers that need
    cross-module context — the contract checkers — resolve modules
    through this)."""

    def __init__(self, files: list[SourceFile]):
        self.files = files
        self.by_module: dict[str, SourceFile] = {}
        for f in files:
            self.by_module.setdefault(f.module, f)

    @classmethod
    def from_sources(cls, named: list[tuple[str, str]]) -> "Project":
        return cls([SourceFile(path=p, source=s) for p, s in named])

    def find_module(self, suffix: str) -> SourceFile | None:
        """Module whose dotted name equals or ends with ``suffix``."""
        if suffix in self.by_module:
            return self.by_module[suffix]
        for name, f in self.by_module.items():
            if name.endswith("." + suffix):
                return f
        return None


def top_level_functions(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def decorator_is_jit(dec: ast.expr) -> bool:
    """True for ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)`` and
    ``@functools.partial(jax.jit, ...)`` decorator expressions."""
    if _name_is_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        fn = dec.func
        is_partial = (isinstance(fn, ast.Name) and fn.id == "partial") \
            or (isinstance(fn, ast.Attribute) and fn.attr == "partial")
        if is_partial and dec.args and _name_is_jit(dec.args[0]):
            return True
        if _name_is_jit(fn):  # @jax.jit(donate_argnums=...) style
            return True
    return False


def _name_is_jit(node: ast.expr) -> bool:
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    return isinstance(node, ast.Attribute) and node.attr == "jit"
