"""Assigned-architecture registry.  Importing this package registers all 10
architectures (plus reduced smoke variants); ``base.get_config(name)``
resolves them."""
from repro.configs.base import (ModelConfig, ShapeCell, SHAPES, get_config,
                                list_archs, count_params, active_params)
from repro.configs import (pixtral_12b, moonshot_v1_16b_a3b,
                           granite_moe_1b_a400m, command_r_35b,
                           h2o_danube_1_8b, gemma3_27b, nemotron_4_340b,
                           whisper_medium, hymba_1_5b, rwkv6_7b)  # noqa: F401

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "get_config", "list_archs",
           "count_params", "active_params"]
