"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144.
Every 6th layer is global full attention; local layers SWA window 1024.
QK-norm, sqrt(d) embedding scaling.  [hf:google/gemma-3-*; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab=262144,
        attn_kind="local_global", global_every=6, window=1024,
        qk_norm=True, emb_scale=True, tie_embeddings=True,
        rope_theta=1_000_000.0,
        fsdp=True, remat="full", microbatch=8, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attn_kind="local_global", global_every=3, window=32,
        qk_norm=True, emb_scale=True, tie_embeddings=True,
        remat="none", scan_chunk=16)


register(full, smoke)
