"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16, 128 meta tokens, SWA everywhere except 3 global layers
(first / middle / last).  [arXiv:2411.13676; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab=32001,
        attn_kind="local_global", global_layers=(0, 15, 31), window=1024,
        ssm_state=16, ssm_conv=4, meta_tokens=128,
        rope_theta=10_000.0,
        remat="dots", microbatch=1, scan_chunk=256)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=257,
        attn_kind="local_global", global_layers=(0, 3), window=32,
        ssm_state=8, ssm_conv=4, meta_tokens=8,
        remat="none", scan_chunk=16)


register(full, smoke)
