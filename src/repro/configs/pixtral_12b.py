"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072,
        rope_theta=1_000_000.0,
        frontend="vision_stub", n_patches=1024,
        remat="full", microbatch=4, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        rope_theta=1_000_000.0,
        frontend="vision_stub", n_patches=8,
        remat="none", scan_chunk=32)


register(full, smoke)
