"""rwkv6-7b [ssm] — RWKV-6 "Finch": attention-free, data-dependent decay.

32L d_model=4096 (64 heads x 64 head_dim) d_ff=14336 vocab=65536.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
        d_ff=14336, vocab=65536,
        rwkv_head_dim=64,
        remat="dots", microbatch=1, scan_chunk=64)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=128, vocab=512,
        rwkv_head_dim=16,
        remat="none", scan_chunk=16)


register(full, smoke)
