"""moonshot-v1-16b-a3b [moe] — Kimi/Moonlight-style 16B-total / 3B-active.

48L d_model=2048 16H (kv=16, head_dim=128 via q_dim=2048) d_ff=1408 (expert)
vocab=163840, MoE 64 experts top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=163840,
        n_experts=64, top_k=6, capacity_factor=1.25,
        rope_theta=50_000.0,
        remat="dots", microbatch=8, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab=512,
        n_experts=8, top_k=2, capacity_factor=1.25,
        remat="none", scan_chunk=32)


register(full, smoke)
