"""command-r-35b [dense] — Cohere Command-R v01.

40L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22528 vocab=256000.
Parallel attention+FFN residual block, no biases, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22528, vocab=256000,
        parallel_block=True, tie_embeddings=True,
        rope_theta=8_000_000.0,
        fsdp=True, remat="full", microbatch=8, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        parallel_block=True, tie_embeddings=True,
        remat="none", scan_chunk=32)


register(full, smoke)
