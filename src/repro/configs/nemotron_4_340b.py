"""nemotron-4-340b [dense] — NVIDIA Nemotron-4 340B.

96L d_model=18432 96H (GQA kv=8, head_dim=192) d_ff=73728 vocab=256000.
Ungated 2-matrix squared-ReLU MLP, as in the original (param count lands at
~341B, matching the advertised 340B).  [arXiv:2402.16819; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
        d_ff=73728, vocab=256000,
        mlp_act="squared_relu", mlp_gated=False,
        rope_theta=10_000.0,
        fsdp=True, optimizer="adafactor", param_dtype="bfloat16",
        remat="full", microbatch=8, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        mlp_act="squared_relu", mlp_gated=False,
        remat="none", scan_chunk=32)


register(full, smoke)
