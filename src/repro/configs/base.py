"""Model/shape configuration system for the LM wing.

One ``ModelConfig`` describes any of the 10 assigned architectures (plus the
reduced smoke variants).  Families:

  dense        — standard decoder LM (GQA, optional SWA / local:global /
                 parallel-block / squared-ReLU)
  moe          — dense backbone with token-choice top-k MoE FFNs
  ssm          — RWKV6 (attention-free, data-dependent decay)
  hybrid       — Hymba (parallel attention + Mamba heads per layer)
  audio        — Whisper-style encoder-decoder (stub conv frontend)
  vlm          — Pixtral-style decoder with stub patch-embedding prefix

Shape cells (assignment): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*``/``long_*`` lower ``serve_step`` (one token against a KV cache of
``seq_len``), not ``train_step``; ``long_500k`` only runs for sub-quadratic
archs (see ``runs_shape``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads

    # attention flavour
    attn_kind: str = "full"       # full | swa | local_global
    window: int = 0               # SWA window (swa / local layers)
    global_every: int = 0         # local_global: every k-th layer is global
    global_layers: tuple[int, ...] = ()   # explicit global positions (hybrid)
    parallel_block: bool = False  # command-r: attn & FFN share the residual
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mlp_act: str = "silu"         # silu | squared_relu | gelu
    mlp_gated: bool = True        # False: 2-matrix MLP (nemotron)
    mlp_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    emb_scale: bool = False       # gemma-style sqrt(d) embedding scaling

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / RWKV / hybrid
    ssm_state: int = 0            # mamba state size (hymba)
    ssm_conv: int = 4             # depthwise conv width
    rwkv_head_dim: int = 64
    meta_tokens: int = 0          # hymba learnable prefix

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_dec_layers: int = 0
    decoder_len: int = 448

    # modality frontend stub
    frontend: str = "none"        # none | audio_stub | vision_stub
    n_patches: int = 0            # vlm: image patch prefix length

    # distribution / memory policy
    fsdp: bool = False            # shard params over the data axis too
    remat: str = "full"           # full | dots | none
    microbatch: int = 1           # grad-accumulation steps per train_step
    optimizer: str = "adamw"      # adamw | adafactor
    param_dtype: str = "bfloat16"
    scan_chunk: int = 512         # attention/recurrence chunk length

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # vocab padding: odd vocab sizes (49155, 51865, 32001, ...) cannot shard
    # over a 16-way model axis, replicating the lm_head matmul and every
    # loss chunk on all 16 devices (§Perf iteration 4).  Parameters are
    # padded to a multiple of this; padded logit columns are masked to -inf
    # in the loss and sliced off in forward()/decode.  0 disables.
    pad_vocab_to: int = 128

    # -- derived ------------------------------------------------------------

    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab_to:
            return self.vocab
        m = self.pad_vocab_to
        return -(-self.vocab // m) * m

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-context decode cell?"""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attn_kind in ("swa", "local_global"):
            return True            # bounded window (global layers seq-shard)
        return False

    def runs_shape(self, shape: str) -> bool:
        if shape == "long_500k":
            return self.sub_quadratic
        return shape in SHAPES

    def layer_kind(self, i: int) -> str:
        """'full' or 'swa' for attention layer i (local_global patterning)."""
        if self.attn_kind == "swa":
            return "swa"
        if self.attn_kind == "local_global":
            if self.global_layers:
                return "full" if i in self.global_layers else "swa"
            return "full" if (i + 1) % self.global_every == 0 else "swa"
        return "full"

    @property
    def global_positions(self) -> tuple[int, ...]:
        return tuple(i for i in range(self.n_layers)
                     if self.layer_kind(i) == "full")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    cfg = full()
    _REGISTRY[cfg.name] = full
    _SMOKE[cfg.name] = smoke


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def count_params(cfg: ModelConfig) -> int:
    """Total parameter count (analytic, matches init; used for 6ND)."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.family == "ssm":                      # rwkv6
        per_layer += 4 * d * d + d * cfg.rwkv_head_dim  # r,k,v,o (+decay lora-ish)
        per_layer += 2 * d * f                   # channel mix
    else:
        qkv = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        per_layer += qkv
        if cfg.family == "hybrid":
            d_in = cfg.q_dim
            per_layer += d * 2 * d_in + d_in * d                 # in/out proj
            per_layer += d_in * (2 * cfg.ssm_state + 1) + d_in * cfg.ssm_conv
        nf = 3 if cfg.mlp_gated else 2
        if cfg.n_experts:
            per_layer += d * cfg.n_experts               # router
            per_layer += cfg.n_experts * nf * d * f      # experts
        else:
            per_layer += nf * d * f
    n = emb + L * per_layer
    if cfg.enc_dec:
        # decoder stack: self + cross attention + ffn
        dec = cfg.n_dec_layers * (2 * (d * cfg.q_dim + 2 * d * cfg.kv_dim
                                       + cfg.q_dim * d) + 3 * d * f)
        n += dec
    return n


def active_params(cfg: ModelConfig) -> int:
    """Active-per-token parameters (MoE: top_k of n_experts) for 6·N_active·D."""
    if not cfg.n_experts:
        return count_params(cfg)
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    nf = 3 if cfg.mlp_gated else 2
    inactive = L * (cfg.n_experts - cfg.top_k) * nf * d * f
    return count_params(cfg) - inactive
