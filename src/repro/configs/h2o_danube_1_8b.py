"""h2o-danube-1.8b [dense] — llama/mistral-mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8, head_dim=80) d_ff=6912 vocab=32000, SWA 4096.
[arXiv:2401.16818; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
        d_ff=6912, vocab=32000,
        attn_kind="swa", window=4096,
        rope_theta=10_000.0,
        remat="dots", microbatch=1, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        attn_kind="swa", window=32,
        remat="none", scan_chunk=16)


register(full, smoke)
