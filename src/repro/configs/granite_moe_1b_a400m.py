"""granite-moe-1b-a400m [moe] — IBM Granite 3.0 1B-A400M base.

24L d_model=1024 16H (GQA kv=8, head_dim=64) d_ff=512 (expert) vocab=49155,
MoE 32 experts top-8, tied embeddings.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49155,
        n_experts=32, top_k=8, capacity_factor=1.25,
        tie_embeddings=True, rope_theta=10_000.0,
        remat="dots", microbatch=2, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=259,
        n_experts=8, top_k=4, capacity_factor=1.25,
        tie_embeddings=True,
        remat="none", scan_chunk=32)


register(full, smoke)
