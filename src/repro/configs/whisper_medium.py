"""whisper-medium [audio] — encoder-decoder with stub conv frontend.

24L (enc) + 24L (dec) d_model=1024 16H (kv=16 = MHA, head_dim=64) d_ff=4096
vocab=51865.  ``input_specs()`` supplies precomputed frame embeddings
(B, seq_len, d) — the conv1d/mel frontend is the assignment-mandated stub.
seq_len applies to the ENCODER; the decoder is fixed at 448 positions.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=51865,
        mlp_act="gelu", enc_dec=True, n_dec_layers=24, decoder_len=448,
        frontend="audio_stub",
        remat="dots", microbatch=1, scan_chunk=512)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=259,
        mlp_act="gelu", enc_dec=True, n_dec_layers=2, decoder_len=16,
        frontend="audio_stub",
        remat="none", scan_chunk=16)


register(full, smoke)
