"""Pallas TPU kernel: banded-DTW anti-diagonal wavefront over a panel.

``engine.dtw_band`` computes exact squared DTW with a Sakoe-Chiba band as
a ``lax.scan`` over anti-diagonals — VPU-shaped math, but XLA-compiled
with (Q, M, n) broadcast intermediates.  This kernel runs the same
wavefront on-chip: one grid cell handles one query against a (TM,) tile
of candidate series, keeping the two rolling diagonals (n, TM) in
registers/VMEM and writing only the (1, TM) corner costs to HBM.

Layout: candidates arrive as a planar diagonal-extraction buffer
``P[..., (n-1) + p, m] = x[m, n-1-p]`` (series axis transposed, reversed,
and zero-padded by n-1 on both ends), so diagonal k's entries
``b[m, k-i]`` for i in [0, n) are the CONTIGUOUS slice
``P[..., 2n-2-k : 3n-2-k, m]`` — a dynamic slice, no in-kernel gather.
The query arrives pre-transposed as (n, Q) so its column block is (n, 1).

Bit-compatibility: every op here (subtract, square, where, minimum, add)
is elementwise — no reductions, no dot — and the op ORDER mirrors
``ref.dtw_band_ref`` exactly, so kernel and oracle agree bit-for-bit
regardless of tiling (locked by np.array_equal in tests/test_kernels.py).

Supports both engine forms: a shared (C, n) panel (every query scans the
same block) and a gathered (Q, M, n) panel (query-major refine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python scalar, not a jnp value: the kernel closes over it, and
# pallas_call rejects captured traced constants
INF = float(jnp.finfo(jnp.float32).max)


def _kernel(qt_ref, p_ref, out_ref, *, n: int, r: int):
    a = qt_ref[...]                                 # (n, 1) query column
    tm = p_ref.shape[-1]
    i = jax.lax.broadcasted_iota(jnp.int32, (n, tm), 0)
    inf_row = jnp.full((1, tm), INF, jnp.float32)

    def shift_down(d):                              # d[i] -> d[i-1]
        return jnp.concatenate([inf_row, d[:-1, :]], axis=0)

    def body(kk, carry):
        prev, prev2 = carry                         # diag k-1, k-2 (by i)
        bk = p_ref[0, pl.ds(2 * n - 2 - kk, n), :]  # b[k-i], i in [0, n)
        jj = kk - i
        valid = (jj >= 0) & (jj < n) & (jnp.abs(i - jj) <= r)
        c = jnp.where(valid, (a - bk) ** 2, INF)
        best = jnp.minimum(jnp.minimum(prev, shift_down(prev)),
                           shift_down(prev2))
        cur = c + jnp.where(kk == 0, 0.0, best)
        cur = jnp.minimum(cur, INF)                 # keep +INF from overflow
        return cur, prev

    init = jnp.full((n, tm), INF, jnp.float32)
    last, _ = jax.lax.fori_loop(0, 2 * n - 1, body, (init, init))
    out_ref[...] = last[n - 1:n, :]                 # cell (n-1, n-1)


@functools.partial(jax.jit,
                   static_argnames=("r", "tile_m", "interpret"))
def dtw_band_panel(q: jax.Array, x: jax.Array, *, r: int, tile_m: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Banded squared-DTW panel. q (Q, n) f32; x either (C, n) — shared
    panel, every query vs every series -> (Q, C) — or (Q, M, n) — gathered
    panel, query i vs its own M series -> (Q, M)."""
    qn, n = q.shape
    shared = x.ndim == 2
    m = x.shape[-2]
    tm = min(tile_m, max(128, m))
    mpad = (-m) % tm
    if mpad:
        pad_shape = x.shape[:-2] + (mpad, n)
        x = jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=-2)
    mp = x.shape[-2]

    # planar diagonal buffer: P[..., (n-1)+p, m] = x[..., m, n-1-p]
    xt = jnp.swapaxes(x, -1, -2).astype(jnp.float32)    # (..., n, Mp)
    rev = xt[..., ::-1, :]
    zpad = jnp.zeros(rev.shape[:-2] + (n - 1, mp), jnp.float32)
    p_buf = jnp.concatenate([zpad, rev, zpad], axis=-2)  # (..., 3n-2, Mp)
    if shared:
        p_buf = p_buf[None]                              # (1, 3n-2, Mp)
        p_map = lambda qi, j: (0, 0, j)
    else:
        p_map = lambda qi, j: (qi, 0, j)

    qt = q.astype(jnp.float32).T                         # (n, Q)
    grid = (qn, mp // tm)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda qi, j: (0, qi)),
            pl.BlockSpec((1, 3 * n - 2, tm), p_map),
        ],
        out_specs=pl.BlockSpec((1, tm), lambda qi, j: (qi, j)),
        out_shape=jax.ShapeDtypeStruct((qn, mp), jnp.float32),
        interpret=interpret,
    )(qt, p_buf)
    return out[:, :m]
