"""Pallas TPU kernel: block-local (dist, id)-lexicographic top-k select.

The refine hot path used to hand the frontier a full (Q, C) masked
distance panel, paying an O((K + C) log(K + C)) lexsort per insert and a
(Q, C) HBM round-trip for candidates that mostly lose.  This kernel
reduces the panel to (Q, k) (dist, id) pairs on-chip, so
``Frontier.insert_topk`` sorts 2k elements instead of K + C and only
(Q, k) ever reaches HBM.

Selection is iterative k-extraction (k is static, so the loop unrolls):
each step takes the row minimum distance, breaks ties toward the
smallest id (ids < 0 sort last, as INT32_MAX keys), and retires the
selected lane.  That is EXACTLY the (dist, id)-lexicographic order of
``frontier._topk_by_dist_id`` — selection is integer-exact, so any
tiling produces the identical result, and feeding the frontier the
selected k instead of all C provably cannot change the final top-k
(see ``Frontier.insert_topk``).  Tiles along C accumulate through the
revisited (Q, k) output block: per tile, select top-k, then re-select
over the 2k concatenation with the running best.

Contract (the engine's masking discipline): within a row, ids >= 0 are
distinct and every lane with id < 0 carries d == +INF — pad lanes are
interchangeable and the kernel may collapse duplicates among them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python scalars, not jnp values: the kernels close over these, and
# pallas_call rejects captured traced constants
INF = float(jnp.finfo(jnp.float32).max)
_PAD_ID_KEY = int(jnp.iinfo(jnp.int32).max)   # sort key for id < 0


def select_topk(d: jax.Array, key: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Unrolled k-extraction over the last axis. d (R, M) f32, key (R, M)
    int32 (id, or INT32_MAX for empty lanes) -> ((R, k), (R, k)) ascending
    by (d, key); emitted ids are the keys, with INT32_MAX mapped to -1."""
    sel_d, sel_i = [], []
    for _ in range(k):
        m = jnp.min(d, axis=-1, keepdims=True)                      # (R, 1)
        kk = jnp.min(jnp.where(d == m, key, _PAD_ID_KEY), axis=-1,
                     keepdims=True)                                 # (R, 1)
        sel_d.append(m)
        sel_i.append(jnp.where(kk == _PAD_ID_KEY, -1, kk))
        kill = (d == m) & (key == kk)
        d = jnp.where(kill, INF, d)
        key = jnp.where(kill, _PAD_ID_KEY, key)
    return jnp.concatenate(sel_d, axis=-1), jnp.concatenate(sel_i, axis=-1)


def _kernel(d_ref, i_ref, out_d_ref, out_i_ref, *, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full(out_d_ref.shape, INF, jnp.float32)
        out_i_ref[...] = jnp.full(out_i_ref.shape, -1, jnp.int32)

    d = d_ref[...]                                              # (TQ, TC)
    ids = i_ref[...]
    td, ti = select_topk(d, jnp.where(ids >= 0, ids, _PAD_ID_KEY), k)
    # merge the tile's top-k into the running top-k (2k-wide re-select)
    rd = jnp.concatenate([out_d_ref[...], td], axis=-1)         # (TQ, 2k)
    ri = jnp.concatenate([out_i_ref[...], ti], axis=-1)
    md, mi = select_topk(rd, jnp.where(ri >= 0, ri, _PAD_ID_KEY), k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit,
                   static_argnames=("k", "tile_q", "tile_c", "interpret"))
def block_topk(d: jax.Array, ids: jax.Array, *, k: int, tile_q: int = 8,
               tile_c: int = 1024, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """d (Q, C) f32 masked panel, ids (Q, C) int32 -> ((Q, k), (Q, k))."""
    qn, c = d.shape
    tq = min(tile_q, max(1, qn))
    tc = min(tile_c, max(128, c))

    qpad = (-qn) % tq
    if qpad:
        d = jnp.concatenate([d, jnp.full((qpad, c), INF, jnp.float32)], 0)
        ids = jnp.concatenate([ids, jnp.full((qpad, c), -1, jnp.int32)], 0)
    cpad = (-c) % tc
    if cpad:
        d = jnp.concatenate(
            [d, jnp.full((d.shape[0], cpad), INF, jnp.float32)], 1)
        ids = jnp.concatenate(
            [ids, jnp.full((ids.shape[0], cpad), -1, jnp.int32)], 1)

    grid = (d.shape[0] // tq, d.shape[1] // tc)
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((d.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(d, ids)
    return out_d[:qn], out_i[:qn]
