"""Pallas TPU kernel: fused selective-SSM scan (Mamba/Hymba hot loop).

The pure-JAX path (models/mamba.py) materializes the discretized
coefficients a, b with shape (B, S, D, N) in HBM — N=16 times the size of
the activations, which is why hymba's train_4k cell is memory-bound by ~50x
(EXPERIMENTS.md §Roofline / §Perf it.3).  This kernel fuses discretization,
recurrence and output contraction in VMEM:

    read : xc (B,S,D), dt (B,S,D), Bm (B,S,N), Cm (B,S,N), A (D,N)
    state: h (TD, N) in VREGs/VMEM, never leaves the chip
    write: y (B,S,D)

HBM traffic ~ (2 + 2N/D)x the activations instead of ~8Nx: a ~30x reduction
for D=100, N=16.

Layout: grid (B, D/TD); each program scans its (S, TD) stripe sequentially
with a fori_loop, carrying h.  ``interpret=True`` validates against
``ref.ssm_scan_ref`` (== models/mamba oracle) in tests/test_kernels.py.

Scope note: forward only (inference prefill / scoring).  The training path
needs a custom VJP (the standard trick: save h at chunk boundaries and
recompute inside — same structure Mamba's CUDA kernel uses); scoped in
DESIGN.md §8 as the next §Perf lever, not wired by default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xc_ref, dt_ref, bm_ref, cm_ref, a_ref, y_ref, *, n_state: int):
    s_len, td = xc_ref.shape
    a_log = a_ref[...]                                 # (TD, N)

    def step(t, h):
        xt = xc_ref[t, :]                              # (TD,)
        dtt = dt_ref[t, :]                             # (TD,)
        bt = bm_ref[t, :]                              # (N,)
        ct = cm_ref[t, :]                              # (N,)
        a = jnp.exp(dtt[:, None] * a_log)              # (TD, N)
        b = (dtt * xt)[:, None] * bt[None, :]          # (TD, N)
        h = a * h + b
        y_ref[t, :] = jnp.sum(h * ct[None, :], axis=1)
        return h

    h0 = jnp.zeros((td, n_state), jnp.float32)
    jax.lax.fori_loop(0, s_len, step, h0)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def ssm_scan(xc: jax.Array, dt: jax.Array, bm: jax.Array, cm: jax.Array,
             a_log: jax.Array, *, tile_d: int = 128,
             interpret: bool = False) -> jax.Array:
    """xc, dt (B, S, D); bm, cm (B, S, N); a_log (D, N) -> y (B, S, D) f32.

    y_t = sum_n h_t[d, n] * cm_t[n],  h_t = exp(dt A) h_{t-1} + dt xc bm.
    D is padded to a tile multiple internally.
    """
    b, s, d = xc.shape
    n = bm.shape[-1]
    td = min(tile_d, d)
    pad = (-d) % td
    f32 = jnp.float32
    if pad:
        zc = jnp.zeros((b, s, pad), xc.dtype)
        xc = jnp.concatenate([xc, zc], axis=-1)
        dt = jnp.concatenate([dt, jnp.zeros((b, s, pad), dt.dtype)], axis=-1)
        a_log = jnp.concatenate([a_log, jnp.zeros((pad, n), a_log.dtype)])
    dp = xc.shape[-1]

    grid = (b, dp // td)
    out = pl.pallas_call(
        functools.partial(_kernel, n_state=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, s, td), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, s, td), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((td, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((None, s, td), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, dp), f32),
        interpret=interpret,
    )(xc.astype(f32), dt.astype(f32), bm.astype(f32), cm.astype(f32),
      a_log.astype(f32))
    return out[..., :d]
