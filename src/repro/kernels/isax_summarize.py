"""Pallas TPU kernel: fused z-norm + PAA + iSAX symbol quantization.

This is Stage 1/2 of the paper's pipeline (IndexBulkLoading workers computing
iSAX summarizations with SIMD) mapped onto the VPU: one grid step summarizes a
tile of series resident in VMEM, producing PAA values and symbols in one pass
over the raw data (the raw tile is read exactly once from HBM).

Layout notes (TPU):
  * the series tile is (TN, n): lane dimension = series points, 128-aligned
    for typical n (128/256/...);
  * breakpoints are passed as a (1, card) row (card=256 = two lanes rows),
    broadcast-compared against PAA values; the trailing slot is a +SENTINEL
    pad so a full 256-wide compare is safe for card-1=255 true breakpoints;
  * quantization = sum(paa >= bp) — a reduction over the lane axis, no gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, bp_ref, paa_ref, sax_ref, *, w: int, normalize: bool):
    x = x_ref[...].astype(jnp.float32)          # (TN, n)
    tn, n = x.shape
    if normalize:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(x * x, axis=-1, keepdims=True) - mu * mu
        x = (x - mu) / jnp.maximum(jnp.sqrt(jnp.maximum(var, 0.0)), 1e-8)
    p = jnp.mean(x.reshape(tn, w, n // w), axis=-1)          # (TN, w)
    bps = bp_ref[...]                                        # (1, card)
    ge = p[:, :, None] >= bps[None, :, :]                    # (TN, w, card)
    s = jnp.sum(ge.astype(jnp.int32), axis=-1)               # (TN, w)
    paa_ref[...] = p
    sax_ref[...] = s


@functools.partial(jax.jit, static_argnames=("w", "card", "normalize", "tile_n", "interpret"))
def isax_summarize(x: jax.Array, *, w: int = 16, card: int = 256,
                   normalize: bool = True, tile_n: int = 256,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(N, n) raw series -> (PAA (N, w) f32, symbols (N, w) int32).

    N is padded to a tile multiple internally; callers receive unpadded
    results.
    """
    from repro.core import isax as _isax

    n_series, n = x.shape
    tile = min(tile_n, max(8, n_series))
    pad = (-n_series) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, n), x.dtype)], axis=0)
    npad = x.shape[0]

    bps = jnp.asarray(_isax.breakpoints(card))               # (card-1,)
    bps = jnp.concatenate([bps, jnp.full((1,), _isax.SENTINEL, jnp.float32)])
    bps = bps.reshape(1, card)

    grid = (npad // tile,)
    paa_out, sax_out = pl.pallas_call(
        functools.partial(_kernel, w=w, normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((1, card), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, w), jnp.float32),
            jax.ShapeDtypeStruct((npad, w), jnp.int32),
        ],
        interpret=interpret,
    )(x, bps)
    return paa_out[:n_series], sax_out[:n_series]
