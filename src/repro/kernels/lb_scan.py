"""Pallas TPU kernel: iSAX lower-bound scan (the ParIS hot loop).

Computes squared MINDIST lower bounds between Q query PAAs and N stored
region envelopes:  out[q, i] = (n/w) * sum_seg max(0, lo - q, q - hi)^2.

This is the paper's SIMD "lower bound distance calculation" phase.  ParIS runs
it over the *entire* SAX array; MESSI runs it over block envelopes and then
only over surviving blocks' series.  Both call this kernel — the input is
either per-series bounds or per-block envelopes.

Layout notes (TPU):
  * bounds are stored PLANAR-TRANSPOSED: lo, hi of shape (w, N) so the lane
    axis is the (large, 128-aligned) series axis and w=16 sits on sublanes —
    a (w, TN) f32 tile is 16x512x4 = 32 KiB, and the compare/max/square/
    accumulate runs full-width on the VPU with zero gathers or transposes;
  * queries live in a small (TQ, w) tile; the (TQ, w, TN) intermediate stays
    in VREGs/VMEM (8x16x512x4 = 256 KiB at the default tile sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, lo_ref, hi_ref, out_ref, *, scale: float):
    q = q_ref[...]                    # (TQ, w)
    lo = lo_ref[...]                  # (w, TN)
    hi = hi_ref[...]                  # (w, TN)
    qe = q[:, :, None]                # (TQ, w, 1)
    d = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
    out_ref[...] = scale * jnp.sum(d * d, axis=1)   # (TQ, TN)


@functools.partial(jax.jit, static_argnames=("n", "tile_q", "tile_n", "interpret"))
def lb_scan(q_paa: jax.Array, lo: jax.Array, hi: jax.Array, *, n: int,
            tile_q: int = 8, tile_n: int = 512,
            interpret: bool = False) -> jax.Array:
    """q_paa (Q, w); lo, hi (w, N) planar bounds -> (Q, N) squared LBs.

    ``n`` is the raw series length (for the n/w MINDIST scale factor).
    Pads Q and N to tile multiples internally; pad rows of lo/hi must already
    be +SENTINEL (the index builder guarantees this) so padded entries yield
    huge LBs and are never selected.
    """
    q_count, w = q_paa.shape
    n_items = lo.shape[1]
    tq = min(tile_q, max(1, q_count))
    tn = min(tile_n, max(128, n_items))

    qpad = (-q_count) % tq
    if qpad:
        q_paa = jnp.concatenate([q_paa, jnp.zeros((qpad, w), q_paa.dtype)], axis=0)
    npad = (-n_items) % tn
    if npad:
        from repro.core.isax import SENTINEL
        pad_lo = jnp.full((w, npad), SENTINEL, lo.dtype)
        pad_hi = jnp.full((w, npad), SENTINEL, hi.dtype)
        lo = jnp.concatenate([lo, pad_lo], axis=1)
        hi = jnp.concatenate([hi, pad_hi], axis=1)

    grid = (q_paa.shape[0] // tq, lo.shape[1] // tn)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=float(n) / float(w)),  # host
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((w, tn), lambda i, j: (0, j)),
            pl.BlockSpec((w, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q_paa.shape[0], lo.shape[1]), jnp.float32),
        interpret=interpret,
    )(q_paa, lo, hi)
    return out[:q_count, :n_items]
