"""Jitted dispatch layer over the Pallas kernels and their jnp oracles.

All core/ code calls these wrappers, never the kernels directly.  Dispatch:

  * mode="auto"      : compiled Pallas on TPU, jnp oracle elsewhere (XLA:CPU
                       compiles the oracle well; interpret-mode Pallas is for
                       correctness, not speed).
  * mode="ref"       : always the pure-jnp oracle.
  * mode="interpret" : Pallas kernels in interpret mode (CPU correctness runs;
                       the tests also call kernels directly with sweeps).
  * mode="pallas"    : compiled Pallas unconditionally (real TPU runs).

The starting mode comes from the ``REPRO_KERNEL_MODE`` environment
variable (validated at import time against the same set) so CI jobs and
benchmark runs can select ref/interpret/pallas without code edits;
``set_mode`` still overrides it at runtime.

Mode is read at TRACE time, so any jitted function that calls these
wrappers bakes the current mode into its cache entries.  Callers that
jit over the dispatch register those functions with
``register_dispatch_cache``; ``set_mode`` clears every registered cache
whenever the mode actually changes, and the ``kernel_mode`` context
manager scopes a set/restore pair for tests and benchmarks.
"""
from __future__ import annotations

import contextlib
import os

# -- jit-cache registry -----------------------------------------------------
# Defined BEFORE the kernel imports: importing this module pulls in
# repro.core (via ref -> isax), whose engine module registers its jitted
# entry points at import time against this partially-initialized module.
#
# Jitted functions whose traces capture the dispatch mode.  set_mode
# clears these on every mode change; without this, a function traced
# under the old mode keeps running the old kernels (mode-sweep tests
# would silently compare a kernel against itself).
_DISPATCH_CACHES: list = []


def register_dispatch_cache(fn) -> None:
    """Register a jitted function whose trace bakes in the kernel mode."""
    _DISPATCH_CACHES.append(fn)


def clear_dispatch_caches() -> None:
    for fn in _DISPATCH_CACHES:
        fn.clear_cache()


import jax

from repro.kernels import ref
from repro.kernels.batch_l2 import batch_l2 as _batch_l2_kernel
from repro.kernels.block_topk import block_topk as _block_topk_kernel
from repro.kernels.dtw_band import dtw_band_panel as _dtw_band_kernel
from repro.kernels.fused_refine import (
    fused_panel_topk as _fused_refine_kernel,
)
from repro.kernels.isax_summarize import isax_summarize as _summ_kernel
from repro.kernels.lb_scan import lb_scan as _lb_kernel

_ENV_VAR = "REPRO_KERNEL_MODE"
_VALID = ("auto", "ref", "interpret", "pallas")


def _mode_from_env() -> str:
    mode = os.environ.get(_ENV_VAR, "auto")
    if mode not in _VALID:
        raise ValueError(
            f"{_ENV_VAR}={mode!r} is not a valid kernel mode; "
            f"choose one of {_VALID}")
    return mode


_MODE = _mode_from_env()


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"mode must be one of {_VALID}")
    if mode != _MODE:
        _MODE = mode
        clear_dispatch_caches()


def get_mode() -> str:
    return _MODE


@contextlib.contextmanager
def kernel_mode(mode: str):
    """Scoped mode switch: sets ``mode`` (clearing registered jit caches)
    and restores the previous mode — clearing again — on exit, even on
    exceptions."""
    old = _MODE
    set_mode(mode)
    try:
        yield
    finally:
        set_mode(old)


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas_kernel, interpret_flag)."""
    if _MODE == "ref":
        return False, False
    if _MODE == "interpret":
        return True, True
    if _MODE == "pallas":
        return True, False
    # auto
    platform = jax.default_backend()
    return (platform == "tpu"), False


def summarize(x: jax.Array, *, w: int, card: int, normalize: bool = True
              ) -> tuple[jax.Array, jax.Array]:
    """(N, n) -> (paa (N, w), sax (N, w) int32)."""
    use, interp = _use_pallas()
    if use:
        return _summ_kernel(x, w=w, card=card, normalize=normalize,
                            interpret=interp)
    return ref.isax_summarize_ref(x, w=w, card=card,
                                  normalize=normalize)


def lb_scan_planar(q_paa: jax.Array, lo: jax.Array, hi: jax.Array, *, n: int
                   ) -> jax.Array:
    """q_paa (Q, w); lo/hi (w, N) -> (Q, N) squared lower bounds."""
    use, interp = _use_pallas()
    if use:
        return _lb_kernel(q_paa, lo, hi, n=n, interpret=interp)
    return ref.lb_scan_ref(q_paa, lo, hi, n=n)


def batch_l2(q: jax.Array, x: jax.Array) -> jax.Array:
    """q (Q, n), x (N, n) -> (Q, N) squared distances."""
    use, interp = _use_pallas()
    if use:
        return _batch_l2_kernel(q, x, interpret=interp)
    return ref.batch_l2_ref(q, x)


def block_topk(d: jax.Array, ids: jax.Array, k: int
               ) -> tuple[jax.Array, jax.Array]:
    """(dist, id)-lexicographic top-k of a masked panel.

    d (Q, C) f32, ids (Q, C) int32 -> (sel_d (Q, k), sel_id (Q, k)).
    Contract: within a row ids >= 0 are distinct and every lane with
    id < 0 carries d == INF (the engine masks both before calling).
    """
    use, interp = _use_pallas()
    if use and k <= d.shape[-1]:
        return _block_topk_kernel(d, ids, k=k, interpret=interp)
    return ref.block_topk_ref(d, ids, k)


def fused_panel_topk(q: jax.Array, q_paa: jax.Array, block: jax.Array,
                     lo: jax.Array, hi: jax.Array, ids: jax.Array,
                     thr: jax.Array, *, k: int, n: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused LB + distance + select over one raw block.

    q (Q, n), q_paa (Q, w), block (C, n), lo/hi (w, C) planar bounds,
    ids (C,) int32, thr (Q,) effective bound (-inf disables a query)
    -> (sel_d (Q, k), sel_id (Q, k), n_live (Q,) int32).
    """
    use, interp = _use_pallas()
    if use and k <= block.shape[0]:
        return _fused_refine_kernel(q, q_paa, block, lo, hi, ids, thr,
                                    k=k, n=n, interpret=interp)
    return ref.fused_panel_topk_ref(q, q_paa, block, lo, hi, ids, thr,
                                    k=k, n=n)


def dtw_panel(q: jax.Array, x: jax.Array, *, r: int) -> jax.Array:
    """Banded squared-DTW panel. q (Q, n); x (C, n) shared -> (Q, C), or
    x (Q, M, n) gathered -> (Q, M)."""
    use, interp = _use_pallas()
    if use:
        return _dtw_band_kernel(q, x, r=r, interpret=interp)
    return ref.dtw_band_panel_ref(q, x, r=r)
