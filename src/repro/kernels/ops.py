"""Jitted dispatch layer over the Pallas kernels and their jnp oracles.

All core/ code calls these wrappers, never the kernels directly.  Dispatch:

  * mode="auto"      : compiled Pallas on TPU, jnp oracle elsewhere (XLA:CPU
                       compiles the oracle well; interpret-mode Pallas is for
                       correctness, not speed).
  * mode="ref"       : always the pure-jnp oracle.
  * mode="interpret" : Pallas kernels in interpret mode (CPU correctness runs;
                       the tests also call kernels directly with sweeps).
  * mode="pallas"    : compiled Pallas unconditionally (real TPU runs).

The starting mode comes from the ``REPRO_KERNEL_MODE`` environment
variable (validated at import time against the same set) so CI jobs and
benchmark runs can select ref/interpret/pallas without code edits;
``set_mode`` still overrides it at runtime.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.batch_l2 import batch_l2 as _batch_l2_kernel
from repro.kernels.isax_summarize import isax_summarize as _summ_kernel
from repro.kernels.lb_scan import lb_scan as _lb_kernel

_ENV_VAR = "REPRO_KERNEL_MODE"
_VALID = ("auto", "ref", "interpret", "pallas")


def _mode_from_env() -> str:
    mode = os.environ.get(_ENV_VAR, "auto")
    if mode not in _VALID:
        raise ValueError(
            f"{_ENV_VAR}={mode!r} is not a valid kernel mode; "
            f"choose one of {_VALID}")
    return mode


_MODE = _mode_from_env()


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"mode must be one of {_VALID}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas_kernel, interpret_flag)."""
    if _MODE == "ref":
        return False, False
    if _MODE == "interpret":
        return True, True
    if _MODE == "pallas":
        return True, False
    # auto
    platform = jax.default_backend()
    return (platform == "tpu"), False


def summarize(x: jax.Array, *, w: int, card: int, normalize: bool = True
              ) -> tuple[jax.Array, jax.Array]:
    """(N, n) -> (paa (N, w), sax (N, w) int32)."""
    use, interp = _use_pallas()
    if use:
        return _summ_kernel(x, w=w, card=card, normalize=normalize,
                            interpret=interp)
    from repro.core import isax
    xx = isax.znorm(x) if normalize else x
    return ref.paa_sax_ref(xx, w, card)


def lb_scan_planar(q_paa: jax.Array, lo: jax.Array, hi: jax.Array, *, n: int
                   ) -> jax.Array:
    """q_paa (Q, w); lo/hi (w, N) -> (Q, N) squared lower bounds."""
    use, interp = _use_pallas()
    if use:
        return _lb_kernel(q_paa, lo, hi, n=n, interpret=interp)
    w = q_paa.shape[1]
    qe = q_paa[:, :, None]
    d = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
    return (float(n) / float(w)) * jnp.sum(d * d, axis=1)


def batch_l2(q: jax.Array, x: jax.Array) -> jax.Array:
    """q (Q, n), x (N, n) -> (Q, N) squared distances."""
    use, interp = _use_pallas()
    if use:
        return _batch_l2_kernel(q, x, interpret=interp)
    return ref.batch_l2_ref(q, x)
