"""Pallas TPU kernel: batched squared-Euclidean distances on the MXU.

The paper's SIMD "real distance calculation" phase.  On TPU the right
formulation is the expanded form

    ||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x

because the cross term is a (TQ, n) x (n, TN) matmul that runs on the MXU at
full throughput, while the norms are cheap VPU row reductions computed in the
same VMEM residency.  Per grid step we stream one (TN, n) tile of raw series
from HBM exactly once — the kernel is HBM-bandwidth-bound at small Q and
MXU-bound for large query batches, matching the roofline analysis in
EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, x_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # (TQ, n)
    x = x_ref[...].astype(jnp.float32)          # (TN, n)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)             # (TQ, 1)
    xx = jnp.sum(x * x, axis=-1)[None, :]                   # (1, TN)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (TQ, TN) on MXU
    out_ref[...] = jnp.maximum(qq + xx - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def batch_l2(q: jax.Array, x: jax.Array, *, tile_q: int = 128,
             tile_n: int = 256, interpret: bool = False) -> jax.Array:
    """q (Q, n), x (N, n) -> (Q, N) squared Euclidean distances, f32."""
    q_count, n = q.shape
    n_items = x.shape[0]
    tq = min(tile_q, max(8, q_count))
    tn = min(tile_n, max(128, n_items))

    qpad = (-q_count) % tq
    if qpad:
        q = jnp.concatenate([q, jnp.zeros((qpad, n), q.dtype)], axis=0)
    npad = (-n_items) % tn
    if npad:
        x = jnp.concatenate([x, jnp.zeros((npad, n), x.dtype)], axis=0)

    grid = (q.shape[0] // tq, x.shape[0] // tn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], x.shape[0]), jnp.float32),
        interpret=interpret,
    )(q, x)
    return out[:q_count, :n_items]
