"""Pallas TPU kernel: fused lower-bound + distance + top-k select.

One pass over a raw (C, n) block tile does everything the engine's ED
``panel_refine`` used to do in three XLA ops with (Q, C) HBM
intermediates between them:

  1. per-series MINDIST lower bound from the planar (w, TC) region
     bounds (VPU, same arithmetic as kernels/lb_scan.py);
  2. the live mask ``(lb < thr) & (id >= 0)`` — a tile with no live lane
     skips the distance matmul entirely (``pl.when``), the kernel-level
     form of the paper's "fewer real distance calculations";
  3. the expanded-form ||q||^2 + ||x||^2 - 2 q.x distances on the MXU
     (same tiling rules as kernels/batch_l2.py — see below);
  4. (dist, id)-lexicographic top-k select of the live lanes
     (kernels/block_topk.py), accumulated across C tiles through the
     revisited (Q, k) output block.

Only (Q, k) candidates and the (Q,) live-lane count ever reach HBM; the
(Q, C) lower-bound and distance panels never materialize.

Bit-compatibility: the default tile sizes REPLICATE kernels/batch_l2.py
(tq = min(128, max(8, Q)), tc = min(256, max(128, C)), zero-padded
operands), so each distance tile is the same dot_general on the same
values the unfused kernel would run — distances agree bit-for-bit with
``ops.batch_l2`` in the same mode, and since selection is integer-exact
and feeding the frontier a top-k subset provably preserves the final
top-k (``Frontier.insert_topk``), the engine's golden parity suite
passes unchanged under both ref and interpret dispatch.

Dead lanes come back as (INF, -1) — exactly what the engine's unfused
path inserted — and callers fold the per-query active mask into ``thr``
as -inf rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.block_topk import INF, _PAD_ID_KEY, select_topk

_NEG_INF = jnp.float32(-jnp.inf)


def _kernel(q_ref, qp_ref, thr_ref, x_ref, lo_ref, hi_ref, id_ref,
            out_d_ref, out_i_ref, out_n_ref, *, k: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full(out_d_ref.shape, INF, jnp.float32)
        out_i_ref[...] = jnp.full(out_i_ref.shape, -1, jnp.int32)
        out_n_ref[...] = jnp.zeros(out_n_ref.shape, jnp.int32)

    qp = qp_ref[...]                                        # (TQ, w)
    lo = lo_ref[...]                                        # (w, TC)
    hi = hi_ref[...]                                        # (w, TC)
    ids = id_ref[...]                                       # (1, TC)
    thr = thr_ref[...]                                      # (TQ, 1)

    qe = qp[:, :, None]                                     # (TQ, w, 1)
    dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
    lb = scale * jnp.sum(dd * dd, axis=1)                   # (TQ, TC)
    live = (lb < thr) & (ids >= 0)                          # (TQ, TC)
    out_n_ref[...] += jnp.sum(live, axis=1, dtype=jnp.int32)[:, None]

    @pl.when(jnp.any(live))
    def _refine():
        q = q_ref[...].astype(jnp.float32)                  # (TQ, n)
        x = x_ref[...].astype(jnp.float32)                  # (TC, n)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)         # (TQ, 1)
        xx = jnp.sum(x * x, axis=-1)[None, :]               # (1, TC)
        cross = jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (TQ, TC) on MXU
        d = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
        d = jnp.where(live, d, INF)
        key = jnp.broadcast_to(jnp.where(live, ids, _PAD_ID_KEY), d.shape)
        td, ti = select_topk(d, key, k)
        rd = jnp.concatenate([out_d_ref[...], td], axis=-1)     # (TQ, 2k)
        ri = jnp.concatenate([out_i_ref[...], ti], axis=-1)
        md, mi = select_topk(rd, jnp.where(ri >= 0, ri, _PAD_ID_KEY), k)
        out_d_ref[...] = md
        out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "n", "tile_q", "tile_c",
                                             "interpret"))
def fused_panel_topk(q: jax.Array, q_paa: jax.Array, block: jax.Array,
                     lo: jax.Array, hi: jax.Array, ids: jax.Array,
                     thr: jax.Array, *, k: int, n: int, tile_q: int = 128,
                     tile_c: int = 256, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q (Q, n); q_paa (Q, w); block (C, n); lo/hi (w, C) planar bounds;
    ids (C,) int32; thr (Q,) effective bound (-inf disables a query).
    -> (sel_d (Q, k), sel_id (Q, k), n_live (Q,) int32)."""
    qn, w = q_paa.shape
    c = block.shape[0]
    # batch_l2's tiling rules — the bit-compatibility contract above
    tq = min(tile_q, max(8, qn))
    tc = min(tile_c, max(128, c))

    qpad = (-qn) % tq
    if qpad:
        q = jnp.concatenate([q, jnp.zeros((qpad, n), q.dtype)], 0)
        q_paa = jnp.concatenate([q_paa, jnp.zeros((qpad, w), q_paa.dtype)], 0)
        thr = jnp.concatenate([thr, jnp.full((qpad,), _NEG_INF)], 0)
    cpad = (-c) % tc
    if cpad:
        block = jnp.concatenate(
            [block, jnp.zeros((cpad, n), block.dtype)], 0)
        lo = jnp.concatenate([lo, jnp.zeros((w, cpad), lo.dtype)], 1)
        hi = jnp.concatenate([hi, jnp.zeros((w, cpad), hi.dtype)], 1)
        ids = jnp.concatenate([ids, jnp.full((cpad,), -1, jnp.int32)], 0)

    grid = (q.shape[0] // tq, block.shape[0] // tc)
    out_d, out_i, out_n = pl.pallas_call(
        functools.partial(_kernel, k=k, scale=float(n) / float(w)),  # host
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, n), lambda i, j: (i, 0)),     # q
            pl.BlockSpec((tq, w), lambda i, j: (i, 0)),     # q_paa
            pl.BlockSpec((tq, 1), lambda i, j: (i, 0)),     # thr
            pl.BlockSpec((tc, n), lambda i, j: (j, 0)),     # block
            pl.BlockSpec((w, tc), lambda i, j: (0, j)),     # lo
            pl.BlockSpec((w, tc), lambda i, j: (0, j)),     # hi
            pl.BlockSpec((1, tc), lambda i, j: (0, j)),     # ids
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((q.shape[0], k), jnp.int32),
            jax.ShapeDtypeStruct((q.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, q_paa, thr[:, None], block, lo, hi, ids[None, :])
    return out_d[:qn], out_i[:qn], out_n[:qn, 0]
