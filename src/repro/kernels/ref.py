"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode on
CPU, shape/dtype sweeps in tests/test_kernels_*.py) and the fallback
implementation on platforms without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import isax

INF = jnp.float32(jnp.finfo(jnp.float32).max)
_PAD_ID_KEY = jnp.int32(jnp.iinfo(jnp.int32).max)   # sort key for id < 0


def paa_sax_ref(x: jax.Array, w: int, card: int) -> tuple[jax.Array, jax.Array]:
    """(N, n) f32 -> PAA (N, w) f32, symbols (N, w) int32. Input already z-normed."""
    p = isax.paa(x, w)
    return p, isax.sax_from_paa(p, card)


def isax_summarize_ref(x: jax.Array, *, w: int, card: int,
                       normalize: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels/isax_summarize.py: optional z-norm + PAA/SAX."""
    xx = isax.znorm(x) if normalize else x
    return paa_sax_ref(xx, w, card)


def lb_block_ref(q_paa: jax.Array, env: jax.Array, n: int) -> jax.Array:
    """Block-envelope lower bounds. q_paa (Q, w), env (B, w, 2) -> (Q, B) f32 (squared)."""
    return isax.mindist_paa_bounds_sq(q_paa[:, None, :], env[None], n)


def lb_series_ref(q_paa: jax.Array, bounds: jax.Array, n: int) -> jax.Array:
    """Per-series lower bounds. q_paa (Q, w), bounds (N, w, 2) -> (Q, N) f32 (squared)."""
    return isax.mindist_paa_bounds_sq(q_paa[:, None, :], bounds[None], n)


def lb_scan_ref(q_paa: jax.Array, lo: jax.Array, hi: jax.Array, *,
                n: int) -> jax.Array:
    """Oracle for kernels/lb_scan.py: planar MINDIST lower bounds.

    q_paa (Q, w); lo/hi (w, N) -> (Q, N) squared bounds with the n/w
    scale factor (``n`` is the raw series length).
    """
    w = q_paa.shape[1]
    qe = q_paa[:, :, None]
    d = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
    return (float(n) / float(w)) * jnp.sum(d * d, axis=1)


def batch_l2_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared Euclidean distances. q (Q, n), x (N, n) -> (Q, N) f32.

    Uses the expanded form ||q||^2 + ||x||^2 - 2 q.x (MXU-friendly, matches the
    kernel) with a clamp at zero for numerical safety.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)          # (Q, 1)
    xx = jnp.sum(x * x, axis=-1)[None, :]                # (1, N)
    cross = q @ x.T                                      # (Q, N) on the MXU
    return jnp.maximum(qq + xx - 2.0 * cross, 0.0)


def batch_l2_exact_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Direct-subtraction oracle (most accurate; O(Q*N*n) memory)."""
    d = q[:, None, :] - x[None, :, :]
    return jnp.sum(d * d, axis=-1)


def topk_by_dist_id(d: jax.Array, ids: jax.Array, k: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Ascending (distance, id)-lexicographic top-k along the last axis.

    Mirrors ``core.frontier._topk_by_dist_id`` (duplicated here because
    ``frontier`` imports ``ops`` imports this module): ids < 0 sort last
    among equal distances and come back normalized to -1.  When k exceeds
    the candidate count the result is padded with (INF, -1) rows.
    """
    m = d.shape[-1]
    if k > m:
        pad = k - m
        d = jnp.concatenate(
            [d, jnp.full(d.shape[:-1] + (pad,), INF, d.dtype)], axis=-1)
        ids = jnp.concatenate(
            [ids, jnp.full(ids.shape[:-1] + (pad,), -1, ids.dtype)], axis=-1)
    key = jnp.where(ids >= 0, ids, _PAD_ID_KEY)
    order = jnp.lexsort((key, d), axis=-1)[..., :k]
    sd = jnp.take_along_axis(d, order, axis=-1)
    si = jnp.take_along_axis(ids, order, axis=-1)
    return sd, jnp.where(si >= 0, si, -1)


def block_topk_ref(d: jax.Array, ids: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels/block_topk.py. d (Q, C) f32, ids (Q, C) int32.

    Contract (the engine's masking discipline): within a row ids >= 0 are
    distinct, and every lane with id < 0 carries d == INF — pad lanes are
    interchangeable, so the kernel may collapse duplicates among them.
    """
    return topk_by_dist_id(d, ids, k)


def fused_panel_topk_ref(q: jax.Array, q_paa: jax.Array, block: jax.Array,
                         lo: jax.Array, hi: jax.Array, ids: jax.Array,
                         thr: jax.Array, *, k: int, n: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels/fused_refine.py: the unfused composition the
    engine's ED ``panel_refine`` ran before fusion.

    q (Q, n), q_paa (Q, w), block (C, n), lo/hi (w, C) planar bounds,
    ids (C,) int32, thr (Q,) effective pruning bound (callers fold the
    per-query active mask in as -inf).  Returns the (dist, id)-lex top-k
    of the live lanes — dead lanes are (INF, -1) — plus the per-query
    live-lane count (the ``series_refined`` stat).
    """
    w = q_paa.shape[-1]
    qe = q_paa[:, :, None]                                    # (Q, w, 1)
    dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
    lb = (n / w) * jnp.sum(dd * dd, axis=1)                   # (Q, C)
    live = (lb < thr[:, None]) & (ids >= 0)[None, :]
    d = jnp.where(live, batch_l2_ref(q, block), INF)
    idm = jnp.where(live, ids[None, :], -1)
    sd, si = topk_by_dist_id(d, idm, k)
    return sd, si, jnp.sum(live, axis=1, dtype=jnp.int32)


def dtw_band_ref(a: jax.Array, b: jax.Array, r: int) -> jax.Array:
    """Exact squared-DTW with band r. a (..., n) vs b (..., n), broadcast.

    Anti-diagonal DP: diag k holds cells (i, j) with i+j == k; each
    diagonal depends only on the previous two, so the whole diagonal
    updates in one vector op.  Cells outside the band are +INF.  The
    Pallas wavefront kernel (kernels/dtw_band.py) mirrors these ops
    EXACTLY — both are pure elementwise arithmetic with no reductions,
    so the two agree bit-for-bit (locked in tests/test_kernels.py).
    """
    a, b = jnp.broadcast_arrays(a, b)
    n = a.shape[-1]
    i_idx = jnp.arange(n)

    def diag_cost(k):
        # cell (i, k-i) for i in [0, n)
        j = k - i_idx
        valid = (j >= 0) & (j < n) & (jnp.abs(i_idx - j) <= r)
        jc = jnp.clip(j, 0, n - 1)
        c = (a[..., i_idx] - jnp.take(b, jc, axis=-1)) ** 2
        return jnp.where(valid, c, INF)

    # dp diagonals indexed by i (row); shifting aligns (i-1, j), (i, j-1),
    # (i-1, j-1)
    def shift_down(d):  # d[i] -> d[i-1]
        return jnp.concatenate([jnp.full(d.shape[:-1] + (1,), INF),
                                d[..., :-1]], axis=-1)

    def body(carry, k):
        prev, prev2 = carry   # diag k-1, diag k-2 (indexed by i)
        c = diag_cost(k)
        best = jnp.minimum(jnp.minimum(prev, shift_down(prev)),
                           shift_down(prev2))
        cur = c + jnp.where(k == 0, 0.0, best)
        cur = jnp.minimum(cur, INF)   # keep +INF cells from overflowing
        return (cur, prev), None

    init_shape = a.shape[:-1] + (n,)
    prev = jnp.full(init_shape, INF)
    prev2 = jnp.full(init_shape, INF)
    (last, second), _ = jax.lax.scan(body, (prev, prev2),
                                     jnp.arange(2 * n - 1))
    return last[..., n - 1]   # cell (n-1, n-1) lives on diag 2n-2 at i=n-1


def dtw_band_panel_ref(q: jax.Array, x: jax.Array, *, r: int
                       ) -> jax.Array:
    """Oracle for kernels/dtw_band.py's panel entry: q (Q, n) against
    a shared panel x (C, n) -> (Q, C), or gathered x (Q, M, n) ->
    (Q, M), by broadcasting into dtw_band_ref."""
    if x.ndim == 2:
        return dtw_band_ref(q[:, None, :], x[None, :, :], r)
    return dtw_band_ref(q[:, None, :], x, r)


def ssm_scan_ref(xc, dt, bm, cm, a_log):
    """Sequential oracle for kernels/ssm_scan.py (same math as
    models/mamba's recurrence with b = dt * xc * B)."""
    f32 = jnp.float32
    xc, dt, bm, cm = (t.astype(f32) for t in (xc, dt, bm, cm))
    a = jnp.exp(dt[..., None] * a_log.astype(f32)[None, None])   # (B,S,D,N)
    b = (dt * xc)[..., None] * bm[:, :, None, :]

    def step(h, inp):
        at, bt, ct = inp
        h = at * h + bt
        return h, jnp.sum(h * ct[:, None, :], axis=-1)

    bsz, s, d = xc.shape
    h0 = jnp.zeros((bsz, d, bm.shape[-1]), f32)
    _, y = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1),
                                   cm.swapaxes(0, 1)))
    return y.swapaxes(0, 1)
