"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode on
CPU, shape/dtype sweeps in tests/test_kernels_*.py) and the fallback
implementation on platforms without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import isax


def paa_sax_ref(x: jax.Array, w: int, card: int) -> tuple[jax.Array, jax.Array]:
    """(N, n) f32 -> PAA (N, w) f32, symbols (N, w) int32. Input already z-normed."""
    p = isax.paa(x, w)
    return p, isax.sax_from_paa(p, card)


def lb_block_ref(q_paa: jax.Array, env: jax.Array, n: int) -> jax.Array:
    """Block-envelope lower bounds. q_paa (Q, w), env (B, w, 2) -> (Q, B) f32 (squared)."""
    return isax.mindist_paa_bounds_sq(q_paa[:, None, :], env[None], n)


def lb_series_ref(q_paa: jax.Array, bounds: jax.Array, n: int) -> jax.Array:
    """Per-series lower bounds. q_paa (Q, w), bounds (N, w, 2) -> (Q, N) f32 (squared)."""
    return isax.mindist_paa_bounds_sq(q_paa[:, None, :], bounds[None], n)


def batch_l2_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared Euclidean distances. q (Q, n), x (N, n) -> (Q, N) f32.

    Uses the expanded form ||q||^2 + ||x||^2 - 2 q.x (MXU-friendly, matches the
    kernel) with a clamp at zero for numerical safety.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)          # (Q, 1)
    xx = jnp.sum(x * x, axis=-1)[None, :]                # (1, N)
    cross = q @ x.T                                      # (Q, N) on the MXU
    return jnp.maximum(qq + xx - 2.0 * cross, 0.0)


def batch_l2_exact_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Direct-subtraction oracle (most accurate; O(Q*N*n) memory)."""
    d = q[:, None, :] - x[None, :, :]
    return jnp.sum(d * d, axis=-1)


def ssm_scan_ref(xc, dt, bm, cm, a_log):
    """Sequential oracle for kernels/ssm_scan.py (same math as
    models/mamba's recurrence with b = dt * xc * B)."""
    f32 = jnp.float32
    xc, dt, bm, cm = (t.astype(f32) for t in (xc, dt, bm, cm))
    a = jnp.exp(dt[..., None] * a_log.astype(f32)[None, None])   # (B,S,D,N)
    b = (dt * xc)[..., None] * bm[:, :, None, :]

    def step(h, inp):
        at, bt, ct = inp
        h = at * h + bt
        return h, jnp.sum(h * ct[:, None, :], axis=-1)

    bsz, s, d = xc.shape
    h0 = jnp.zeros((bsz, d, bm.shape[-1]), f32)
    _, y = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1),
                                   cm.swapaxes(0, 1)))
    return y.swapaxes(0, 1)
