"""Render the dry-run JSONL records into the EXPERIMENTS.md tables."""
import json
import sys


def load(path):
    rows = []
    seen = {}
    for line in open(path):
        r = json.loads(line)
        seen[(r["arch"], r["shape"], r.get("mesh", "?"))] = r  # last wins
    return list(seen.values())


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def roofline_table(rows, mesh):
    out = ["| arch | shape | kind | peak GiB/dev | FLOPs/dev | compute ms | "
           "memory ms | coll ms | bottleneck | useful |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_bytes(r['bytes_per_device']['peak'])} | "
            f"{r['flops_per_dev']:.2e} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} |")
    return "\n".join(out)


def summary(rows):
    ok = [r for r in rows if r.get("status") == "ok"]
    fail = [r for r in rows if r.get("status") != "ok"]
    return f"{len(ok)} ok / {len(fail)} failed"


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1
                else "experiments/dryrun_final.jsonl")
    print("### single-pod 16x16 (256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n### multi-pod 2x16x16 (512 chips)\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n", summary(rows))
