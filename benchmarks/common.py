"""Shared benchmark utilities: timing, dataset cache, CSV output."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           **kw) -> tuple[float, object]:
    """Median wall time (s) of ``fn(*args)`` with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def write_rows(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
