"""Shared benchmark utilities: timing, table/JSON output, and the
``BenchRunner`` CLI harness every ``bench_*`` driver builds on."""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable

import jax
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def csv_ints(s: str) -> tuple[int, ...]:
    """argparse type for comma-separated int sweeps, e.g. --k 1,5,32."""
    return tuple(int(x) for x in s.split(","))


def csv_strs(s: str) -> tuple[str, ...]:
    return tuple(s.split(","))


class BenchRunner:
    """The per-driver CLI boilerplate, hoisted: argparse construction,
    the ``--out`` JSON artifact emission (``BENCH_*.json`` in CI), and
    the exit-code contract — previously copy-pasted across the seven
    ``bench_*`` drivers.

    >>> def main(argv=None):
    ...     return (BenchRunner(__doc__)
    ...             .arg("--sizes", type=csv_ints, default=(50_000,))
    ...             .main(lambda a: run(sizes=a.sizes), argv))
    """

    def __init__(self, description: str | None = None):
        self.ap = argparse.ArgumentParser(
            description=description,
            formatter_class=argparse.RawDescriptionHelpFormatter)
        self.ap.add_argument(
            "--out", default=None,
            help="also write rows to this JSON path "
                 "(e.g. BENCH_query.json for the CI artifact)")

    def arg(self, *args, **kw) -> "BenchRunner":
        self.ap.add_argument(*args, **kw)
        return self

    def main(self, run: Callable[[argparse.Namespace], list[dict]],
             argv=None) -> int:
        args = self.ap.parse_args(argv)
        rows = run(args)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"wrote {args.out}")
        return 0


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           **kw) -> tuple[float, object]:
    """Median wall time (s) of ``fn(*args)`` with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def write_rows(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
