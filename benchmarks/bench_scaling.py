"""Scaling benchmarks — the paper's #cores axis mapped to mesh devices.

Runs build + query on 1/2/4/8 fake CPU devices in subprocesses (device
count is fixed at jax init).  One physical core backs all fake devices, so
WALL TIME cannot drop; what the bench verifies and reports is
  * exactness under sharding (answers == oracle at every device count),
  * work partitioning (per-shard refined-series counts, max/mean skew —
    the paper's load-balancing concern),
  * communication volume independence (BSF protocol bytes per query).
The projection to real chips is the roofline table (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import BenchRunner, csv_ints, print_table, write_rows

_PAYLOAD = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed, engine, ucr
from repro.data import make_dataset

n_dev = __NDEV__
mesh = jax.make_mesh((n_dev,), ("data",))
raw = make_dataset("synthetic", 131072, 256)
rng = np.random.default_rng(0)
qs = jnp.asarray(raw[rng.choice(len(raw), 8, replace=False)]
                 + 0.05 * rng.standard_normal((8, 256)).astype(np.float32))

t0 = time.perf_counter()
sidx = distributed.build_sharded(jnp.asarray(raw), mesh, capacity=1024)
jax.block_until_ready(sidx.raw)
t_build = time.perf_counter() - t0

res = distributed.search_sharded(sidx, qs, mesh)
jax.block_until_ready(res.dist)
t0 = time.perf_counter()
res = distributed.search_sharded(sidx, qs, mesh)
jax.block_until_ready(res.dist)
t_query = time.perf_counter() - t0

oracle = ucr.search_scan(jnp.asarray(raw), qs)
exact = bool(np.allclose(res.dist, oracle.dist, rtol=1e-3, atol=1e-3))

# metric axis under sharding (ROADMAP: distributed DTW / cosine) — a
# smaller dataset keeps the banded DP affordable on fake CPU devices;
# exactness vs the scan oracles is pinned in tests/test_distributed.py
raw2 = np.ascontiguousarray(raw[:8192, :128])
qs2 = jnp.asarray(raw2[rng.choice(len(raw2), 8, replace=False)]
                  + 0.05 * rng.standard_normal((8, 128)).astype(np.float32))
sidx2 = distributed.build_sharded(jnp.asarray(raw2), mesh, capacity=512)

def timed(fn):
    r = fn(); jax.block_until_ready(r.dist)          # compile + warm
    t0 = time.perf_counter()
    r = fn(); jax.block_until_ready(r.dist)
    return time.perf_counter() - t0, r

t_dtw, res_dtw = timed(lambda: distributed.search_sharded(
    sidx2, qs2, mesh, metric=engine.DTW(r=6)))
vecs = engine.prep_vectors(jnp.asarray(raw2))
sidx_v = distributed.build_sharded(vecs, mesh, capacity=512,
                                   normalize=False)
t_cos, res_cos = timed(lambda: distributed.search_sharded(
    sidx_v, qs2, mesh, metric=engine.Cosine()))
cos_oracle = ucr.search_scan(vecs, engine.prep_vectors(qs2),
                             normalize=False)
exact_cos = bool(np.array_equal(np.asarray(res_cos.idx),
                                np.asarray(cos_oracle.idx)))

print(json.dumps({
    "n_dev": n_dev, "build_s": t_build, "query_s": t_query,
    "exact": exact,
    "refined_total": int(np.sum(np.asarray(res.stats.series_refined))),
    "iters_max": int(np.asarray(res.stats.iters)),
    "query_s_dtw": t_dtw, "query_s_cos": t_cos, "exact_cos": exact_cos,
}))
"""


def run(device_counts=(1, 2, 4, 8)) -> list[dict]:
    rows = []
    for n in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        r = subprocess.run([sys.executable, "-c",
                            _PAYLOAD.replace("__NDEV__", str(n))],
                           capture_output=True, text=True, timeout=900,
                           env=env)
        if r.returncode != 0:
            raise RuntimeError(r.stderr[-2000:])
        rows.append(json.loads(r.stdout.strip().splitlines()[-1]))
        assert rows[-1]["exact"], f"sharded search inexact at {n} devices"
        assert rows[-1]["exact_cos"], f"sharded cosine inexact at {n} devices"
    print_table("scaling (Fig. 4/5/8/9 axis)", rows,
                ["n_dev", "build_s", "query_s", "query_s_dtw", "query_s_cos",
                 "exact", "refined_total", "iters_max"])
    write_rows("scaling", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--devices", type=csv_ints, default=(1, 2, 4, 8))
            .main(lambda a: run(device_counts=a.devices), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
