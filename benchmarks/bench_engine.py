"""Query-engine matrix benchmark: wall-time per metric x schedule x
backend cell (core/engine.py).

One dataset, every implemented cell of the matrix the engine composes —
ED / DTW / Cosine, query-major / block-major / flat, device-resident /
cached-blocks (plus the two-round distributed out-of-core protocol over
two shard sessions) — each cell's exactness asserted against its oracle
before it is timed.  The JSON rows are the per-cell trajectory CI
tracks (`BENCH_engine.json`).

    PYTHONPATH=src python -m benchmarks.bench_engine \\
        --size 20000 --k 5 --out BENCH_engine.json
"""
from __future__ import annotations

import os
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import BenchRunner, print_table, timeit, write_rows
from repro import storage
from repro.core import distributed, dtw as D, engine, vector
from repro.core import frontier as frontier_lib
from repro.core.frontier import Frontier
from repro.core.paris import search_paris
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.data import make_dataset


def run(n: int = 20_000, length: int = 128, n_queries: int = 8,
        capacity: int = 256, k: int = 5, r: int = 6,
        workdir: str | None = None) -> list[dict]:
    tmp = workdir or tempfile.mkdtemp(prefix="bench_engine_")
    try:
        return _run(tmp, n=n, length=length, n_queries=n_queries,
                    capacity=capacity, k=k, r=r)
    finally:
        if workdir is None:
            shutil.rmtree(tmp, ignore_errors=True)


def _run(tmp: str, *, n: int, length: int, n_queries: int, capacity: int,
         k: int, r: int) -> list[dict]:
    raw = make_dataset("synthetic", n, length)
    rng = np.random.default_rng(99)
    qs = jnp.asarray(raw[rng.choice(n, n_queries, replace=False)]
                     + 0.05 * rng.standard_normal((n_queries, length))
                     .astype(np.float32))
    raw_j = jnp.asarray(raw)
    idx = core.build(raw_j, capacity=capacity)

    index_path = os.path.join(tmp, f"engine_{n}.dsix")
    storage.save_index(idx, index_path)
    opened = storage.open_index(index_path)

    def build_shards(cap: int, suffix: str) -> list[str]:
        """Two on-disk shard files: disjoint halves, global ids."""
        half = n // 2
        paths = []
        for s in range(2):
            ids = jnp.arange(s * half, (s + 1) * half, dtype=jnp.int32)
            sidx = core.build(raw_j[s * half:(s + 1) * half],
                              capacity=cap, ids=ids)
            path = os.path.join(tmp, f"engine_{n}_{suffix}{s}.dsix")
            storage.save_index(sidx, path)
            paths.append(path)
        return paths

    # shard files for the distributed-ooc cell
    shard_paths = build_shards(capacity, "shard")

    # embeddings for the cosine cells: the raw series reinterpreted as
    # length-d vectors (d == length, divisible by w)
    vidx = vector.build_vector_index(raw_j, capacity=capacity)
    v_path = os.path.join(tmp, f"engine_{n}_vec.dsix")
    storage.save_index(vidx, v_path)
    v_opened = storage.open_index(v_path)

    oracle = search_scan(raw_j, qs, k=k)
    oracle_dtw = D.search_dtw(idx, qs, r=r, k=k)
    oracle_cos = vector.search_vectors(vidx, qs, k=k)

    def ooc(metric=None):
        return lambda: storage.ooc_search(opened, qs, k=k, metric=metric,
                                          cache_blocks=8)

    def ooc_cos():
        return storage.ooc_search(v_opened, qs, k=k,
                                  metric=engine.Cosine(), cache_blocks=8)

    def dist_ooc():
        sessions = [storage.SearchSession(storage.open_index(p),
                                          cache_blocks=8)
                    for p in shard_paths]
        try:
            return distributed.search_sharded_ooc(sessions, qs, k=k)
        finally:
            for s in sessions:
                s.close()

    cells = [
        ("ed", "query_major", "device",
         lambda: core.search(idx, qs, k=k), oracle),
        ("ed", "block_major", "device",
         lambda: search_block_major(idx, qs, k=k), oracle),
        ("ed", "flat", "device",
         lambda: search_paris(idx, qs, k=k), oracle),
        ("ed", "block_major", "cached", ooc(), oracle),
        ("ed", "block_major", "cached_x2_shards", dist_ooc, oracle),
        ("dtw", "query_major", "device",
         lambda: D.search_dtw(idx, qs, r=r, k=k), oracle_dtw),
        ("dtw", "block_major", "cached",
         ooc(engine.DTW(r=r)), oracle_dtw),
        ("cosine", "query_major", "device",
         lambda: vector.search_vectors(vidx, qs, k=k), oracle_cos),
        ("cosine", "block_major", "cached", ooc_cos, oracle_cos),
    ]

    rows = []
    for metric, schedule, backend, fn, want in cells:
        t, res = timeit(fn, iters=2)
        assert np.array_equal(np.asarray(res.idx),
                              np.asarray(want.idx)), \
            f"exactness! {metric}/{schedule}/{backend}"
        rows.append({
            "metric": metric, "schedule": schedule, "backend": backend,
            "n_series": n, "k": k, "ms_per_query": t / n_queries * 1e3,
            "refined_frac": float(np.mean(np.asarray(
                res.stats.series_refined))) / n,
        })

    print_table("query-engine matrix (metric x schedule x backend)", rows,
                ["metric", "schedule", "backend", "n_series", "k",
                 "ms_per_query", "refined_frac"])

    # finer-grained shard files for the protocol before/after cell:
    # the global round-1 bound prunes at block granularity, so the
    # savings need smaller blocks (and the paper's headline k=1) to be
    # visible on this dataset size
    proto_paths = build_shards(min(64, capacity), "proto")
    oracle1 = search_scan(raw_j, qs, k=1)
    rows += _protocol_before_after(proto_paths, qs, oracle1,
                                   n=n, n_queries=n_queries)
    write_rows("engine", rows)
    return rows


class _RefineCounter:
    """Count host-level panel-refine dispatches (one per refined block)."""

    def __enter__(self):
        self.count = 0
        self._orig = engine._cached_refine_step

        def counting(*a, **kw):
            self.count += 1
            return self._orig(*a, **kw)

        engine._cached_refine_step = counting
        return self

    def __exit__(self, *exc):
        engine._cached_refine_step = self._orig


def _protocol_before_after(shard_paths, qs, oracle, *, n, n_queries):
    """The two-round protocol with round-1 reuse (production) vs the
    PR-4 shape (round 2 recomputes stage A) vs blind shards (no
    protocol), measured in the paper's serving shape — one 1-NN query
    at a time, cold sessions: same answers, strictly fewer device
    refines than no-reuse (stage A runs once, not twice) and strictly
    fewer disk bytes than blind (the global round-1 bound prunes blocks
    a shard's local bound keeps)."""
    qs_h = np.asarray(qs)

    def sessions():
        return [storage.SearchSession(storage.open_index(p), cache_blocks=8)
                for p in shard_paths]

    def merge(results):
        front = Frontier(results[0].dist, results[0].idx)
        for r in results[1:]:
            front = frontier_lib.merge(front, Frontier(r.dist, r.idx))
        return front

    def per_query(protocol):
        idx, disk_bytes = [], 0
        for i in range(qs_h.shape[0]):
            ss = sessions()
            try:
                idx.append(np.asarray(protocol(ss, jnp.asarray(
                    qs_h[i:i + 1]))))
                disk_bytes += sum(s.cache.disk_bytes for s in ss)
            finally:
                for s in ss:
                    s.close()
        return np.concatenate(idx, axis=0), disk_bytes

    def reuse(ss, q1):
        return distributed.search_sharded_ooc(ss, q1, k=1).idx

    def noreuse(ss, q1):
        thr_g = np.minimum.reduce(
            [np.asarray(s.approximate_threshold(q1, k=1)) for s in ss])
        return merge([s.search(q1, k=1, initial_threshold=jnp.asarray(thr_g))
                      for s in ss]).ids

    def blind(ss, q1):
        return merge([s.search(q1, k=1) for s in ss]).ids

    rows, meas = [], {}
    for name, proto in (("protocol_reuse", reuse),
                        ("protocol_noreuse", noreuse),
                        ("blind_shards", blind)):
        with _RefineCounter() as rc:       # also the compile warmup pass
            idx, disk_bytes = per_query(proto)
        t, _ = timeit(per_query, proto, warmup=0, iters=1)
        assert np.array_equal(idx, np.asarray(oracle.idx)), \
            f"exactness! {name}"
        meas[name] = (rc.count, disk_bytes)
        rows.append({
            "metric": "ed", "schedule": "block_major", "backend": name,
            "n_series": n, "k": 1, "ms_per_query": t / n_queries * 1e3,
            "panel_refines": rc.count, "disk_bytes": disk_bytes,
        })

    # the reuse win, asserted: fewer device refines than re-running
    # stage A in round 2, fewer disk bytes than skipping the protocol
    assert meas["protocol_reuse"][0] < meas["protocol_noreuse"][0], meas
    assert meas["protocol_reuse"][1] < meas["blind_shards"][1], meas
    print_table("two-round protocol: round-1 reuse vs PR-4 vs blind "
                "(2 ooc shards, per-query k=1, cold)", rows,
                ["backend", "k", "ms_per_query", "panel_refines",
                 "disk_bytes"])
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=20_000)
            .arg("--length", type=int, default=128)
            .arg("--queries", type=int, default=8)
            .arg("--capacity", type=int, default=256)
            .arg("--k", type=int, default=5)
            .arg("--band", type=int, default=6)
            .main(lambda a: run(n=a.size, length=a.length,
                                n_queries=a.queries, capacity=a.capacity,
                                k=a.k, r=a.band), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
