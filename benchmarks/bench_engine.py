"""Query-engine matrix benchmark: wall-time per metric x schedule x
backend cell (core/engine.py).

One dataset, every implemented cell of the matrix the engine composes —
ED / DTW / Cosine, query-major / block-major / flat, device-resident /
cached-blocks (plus the two-round distributed out-of-core protocol over
two shard sessions) — each cell's exactness asserted against its oracle
before it is timed.  The JSON rows are the per-cell trajectory CI
tracks (`BENCH_engine.json`).

    PYTHONPATH=src python -m benchmarks.bench_engine \\
        --size 20000 --k 5 --out BENCH_engine.json
"""
from __future__ import annotations

import os
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import BenchRunner, print_table, timeit, write_rows
from repro import storage
from repro.core import distributed, dtw as D, engine, vector
from repro.core.paris import search_paris
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.data import make_dataset


def run(n: int = 20_000, length: int = 128, n_queries: int = 8,
        capacity: int = 256, k: int = 5, r: int = 6,
        workdir: str | None = None) -> list[dict]:
    tmp = workdir or tempfile.mkdtemp(prefix="bench_engine_")
    try:
        return _run(tmp, n=n, length=length, n_queries=n_queries,
                    capacity=capacity, k=k, r=r)
    finally:
        if workdir is None:
            shutil.rmtree(tmp, ignore_errors=True)


def _run(tmp: str, *, n: int, length: int, n_queries: int, capacity: int,
         k: int, r: int) -> list[dict]:
    raw = make_dataset("synthetic", n, length)
    rng = np.random.default_rng(99)
    qs = jnp.asarray(raw[rng.choice(n, n_queries, replace=False)]
                     + 0.05 * rng.standard_normal((n_queries, length))
                     .astype(np.float32))
    raw_j = jnp.asarray(raw)
    idx = core.build(raw_j, capacity=capacity)

    index_path = os.path.join(tmp, f"engine_{n}.dsix")
    storage.save_index(idx, index_path)
    opened = storage.open_index(index_path)

    # shard files for the distributed-ooc cell (disjoint halves, global ids)
    half = n // 2
    shard_paths = []
    for s in range(2):
        ids = jnp.arange(s * half, (s + 1) * half, dtype=jnp.int32)
        sidx = core.build(raw_j[s * half:(s + 1) * half],
                          capacity=capacity, ids=ids)
        path = os.path.join(tmp, f"engine_{n}_shard{s}.dsix")
        storage.save_index(sidx, path)
        shard_paths.append(path)

    # embeddings for the cosine cells: the raw series reinterpreted as
    # length-d vectors (d == length, divisible by w)
    vidx = vector.build_vector_index(raw_j, capacity=capacity)
    v_path = os.path.join(tmp, f"engine_{n}_vec.dsix")
    storage.save_index(vidx, v_path)
    v_opened = storage.open_index(v_path)

    oracle = search_scan(raw_j, qs, k=k)
    oracle_dtw = D.search_dtw(idx, qs, r=r, k=k)
    oracle_cos = vector.search_vectors(vidx, qs, k=k)

    def ooc(metric=None):
        return lambda: storage.ooc_search(opened, qs, k=k, metric=metric,
                                          cache_blocks=8)

    def ooc_cos():
        return storage.ooc_search(v_opened, qs, k=k,
                                  metric=engine.Cosine(), cache_blocks=8)

    def dist_ooc():
        sessions = [storage.SearchSession(storage.open_index(p),
                                          cache_blocks=8)
                    for p in shard_paths]
        try:
            return distributed.search_sharded_ooc(sessions, qs, k=k)
        finally:
            for s in sessions:
                s.close()

    cells = [
        ("ed", "query_major", "device",
         lambda: core.search(idx, qs, k=k), oracle),
        ("ed", "block_major", "device",
         lambda: search_block_major(idx, qs, k=k), oracle),
        ("ed", "flat", "device",
         lambda: search_paris(idx, qs, k=k), oracle),
        ("ed", "block_major", "cached", ooc(), oracle),
        ("ed", "block_major", "cached_x2_shards", dist_ooc, oracle),
        ("dtw", "query_major", "device",
         lambda: D.search_dtw(idx, qs, r=r, k=k), oracle_dtw),
        ("dtw", "block_major", "cached",
         ooc(engine.DTW(r=r)), oracle_dtw),
        ("cosine", "query_major", "device",
         lambda: vector.search_vectors(vidx, qs, k=k), oracle_cos),
        ("cosine", "block_major", "cached", ooc_cos, oracle_cos),
    ]

    rows = []
    for metric, schedule, backend, fn, want in cells:
        t, res = timeit(fn, iters=2)
        assert np.array_equal(np.asarray(res.idx),
                              np.asarray(want.idx)), \
            f"exactness! {metric}/{schedule}/{backend}"
        rows.append({
            "metric": metric, "schedule": schedule, "backend": backend,
            "n_series": n, "k": k, "ms_per_query": t / n_queries * 1e3,
            "refined_frac": float(np.mean(np.asarray(
                res.stats.series_refined))) / n,
        })

    print_table("query-engine matrix (metric x schedule x backend)", rows,
                ["metric", "schedule", "backend", "n_series", "k",
                 "ms_per_query", "refined_frac"])
    write_rows("engine", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=20_000)
            .arg("--length", type=int, default=128)
            .arg("--queries", type=int, default=8)
            .arg("--capacity", type=int, default=256)
            .arg("--k", type=int, default=5)
            .arg("--band", type=int, default=6)
            .main(lambda a: run(n=a.size, length=a.length,
                                n_queries=a.queries, capacity=a.capacity,
                                k=a.k, r=a.band), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
