"""DTW query answering over the unchanged Euclidean index — the paper's §V
claim ("index a dataset once, answer both Euclidean and DTW queries")."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import BenchRunner, print_table, timeit, write_rows
from repro.core import dtw as D
from repro.core import isax
from repro.data import make_dataset


def run(n: int = 20_000, length: int = 128, r: int = 6,
        n_queries: int = 8) -> list[dict]:
    raw = make_dataset("synthetic", n, length)
    rng = np.random.default_rng(1)
    qs = jnp.asarray(raw[rng.choice(n, n_queries, replace=False)]
                     + 0.05 * rng.standard_normal((n_queries, length))
                     .astype(np.float32))
    raw_j = jnp.asarray(raw)
    idx = core.build(raw_j, capacity=512)

    def brute(qs):
        qz, xz = isax.znorm(qs), isax.znorm(raw_j)
        return D.dtw_band(qz[:, None, :], xz[None], r)

    t_index, res = timeit(D.search_dtw, idx, qs, r=r, iters=2)
    t_brute, bf = timeit(brute, qs, iters=2)
    got = np.asarray(res.idx[:, 0])
    want = np.argmin(np.asarray(bf), axis=1)
    assert np.array_equal(got, want), "DTW exactness"
    rows = [{
        "n_series": n, "band_r": r,
        "index_ms_per_q": t_index / n_queries * 1e3,
        "brute_ms_per_q": t_brute / n_queries * 1e3,
        "speedup": t_brute / t_index,
        "blocks_visited": float(np.mean(np.asarray(
            res.stats.blocks_visited))),
    }]
    print_table("DTW via Euclidean index (paper SV)", rows,
                ["n_series", "band_r", "index_ms_per_q", "brute_ms_per_q",
                 "speedup", "blocks_visited"])
    write_rows("dtw", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=20_000)
            .arg("--length", type=int, default=128)
            .arg("--band", type=int, default=6)
            .arg("--queries", type=int, default=8)
            .main(lambda a: run(n=a.size, length=a.length, r=a.band,
                                n_queries=a.queries), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
