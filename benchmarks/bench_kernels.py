"""Fused refine kernels (DESIGN.md §8): per-site fused-vs-unfused wall
time and HBM-traffic estimates, with exactness asserted at every site.

Four sites, mirroring where core/engine.py swapped the kernels in:

  * ``panel_refine`` (ED, block-major): unfused LB panel -> mask ->
    distance panel -> (K+C)-wide frontier insert, vs the fused
    ``ops.fused_panel_topk`` + ``insert_topk`` (2k-wide merge);
  * the flat-chunk select (``run_flat`` / stage-A seeding): full-panel
    ``insert_batch`` vs ``ops.block_topk`` + ``insert_topk``;
  * the banded-DTW panel: the lax.scan wavefront (the oracle, what XLA
    compiles on CPU) vs the Pallas wavefront kernel in interpret mode —
    a correctness assert, bit-for-bit (compiled-Pallas speed needs a
    TPU; interpret timings measure the emulator, so they are reported
    but not a speed claim);
  * the DTW x flat driver cell (``dtw.search_dtw_flat`` vs the
    query-major ``search_dtw``), closing the bench matrix.

The select-fusion win is mode-independent: whatever computes the
distances, the frontier merge drops from sorting K+C candidates per
block to 2k, and the (Q, C) panels stop round-tripping through HBM —
the ``hbm_bytes_*`` columns estimate that traffic (f32 panels, f32+i32
candidate pairs)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import BenchRunner, print_table, timeit, write_rows
from repro.core import dtw as D
from repro.core import engine, isax
from repro.core import frontier as frontier_lib
from repro.core.frontier import INF
from repro.data import make_dataset
from repro.kernels import ops


def _panel_inputs(n_series, length, n_queries, w=16, seed=7):
    raw = jnp.asarray(make_dataset("synthetic", n_series, length))
    rng = np.random.default_rng(seed)
    qs = jnp.asarray(np.asarray(raw[rng.choice(n_series, n_queries,
                                               replace=False)])
                     + 0.05 * rng.standard_normal(
                         (n_queries, length)).astype(np.float32))
    xn, qn = isax.znorm(raw), isax.znorm(qs)
    _, _, bounds = isax.summarize(xn, w=w)
    return (qn, isax.paa(qn, w), xn, bounds[..., 0].T, bounds[..., 1].T,
            jnp.arange(n_series, dtype=jnp.int32))


def _bench_panel_refine(n_series, length, n_queries, k):
    q, q_paa, x, lo, hi, ids = _panel_inputs(n_series, length, n_queries)
    qn, c = q.shape[0], x.shape[0]
    thr = jnp.full((qn,), 0.25 * length, jnp.float32)  # realistic pruning
    f0 = frontier_lib.init(qn, k)

    @jax.jit
    def unfused(f):
        w = q_paa.shape[-1]
        qe = q_paa[:, :, None]
        dd = jnp.maximum(jnp.maximum(lo[None] - qe, qe - hi[None]), 0.0)
        lb = (length / w) * jnp.sum(dd * dd, axis=1)        # (Q, C) panel
        live = (lb < thr[:, None]) & (ids >= 0)[None, :]
        d = jnp.where(live, ops.batch_l2(q, x), INF)        # (Q, C) panel
        return f.insert(d, jnp.where(live, ids[None, :], -1))

    @jax.jit
    def fused(f):
        sd, si, _ = ops.fused_panel_topk(q, q_paa, x, lo, hi, ids, thr,
                                         k=k, n=length)
        return f.insert_topk(sd, si)

    t_u, f_u = timeit(unfused, f0)
    t_f, f_f = timeit(fused, f0)
    assert np.array_equal(np.asarray(f_u.dists), np.asarray(f_f.dists))
    assert np.array_equal(np.asarray(f_u.ids), np.asarray(f_f.ids))
    return {
        "site": "panel_refine_ed", "Q": qn, "C": c, "k": k,
        "mode": ops.get_mode(),
        "unfused_ms": t_u * 1e3, "fused_ms": t_f * 1e3,
        "speedup": t_u / t_f,
        "sort_width_unfused": k + c, "sort_width_fused": 2 * k,
        "hbm_bytes_unfused": 2 * qn * c * 4 + qn * (k + c) * 8,
        "hbm_bytes_fused": qn * k * 8 + qn * 4 + qn * 2 * k * 8,
        "exact": True,
    }


def _bench_flat_select(n_series, length, n_queries, k):
    q, _, x, _, _, ids = _panel_inputs(n_series, length, n_queries, seed=8)
    qn, c = q.shape[0], x.shape[0]
    d = ops.batch_l2(q, x)
    idm = jnp.broadcast_to(ids[None, :], (qn, c))
    f0 = frontier_lib.init(qn, k)

    full = jax.jit(lambda f: f.insert(d, idm))
    sel = jax.jit(lambda f: f.insert_topk(*ops.block_topk(d, idm, k)))
    t_u, f_u = timeit(full, f0)
    t_f, f_f = timeit(sel, f0)
    assert np.array_equal(np.asarray(f_u.dists), np.asarray(f_f.dists))
    assert np.array_equal(np.asarray(f_u.ids), np.asarray(f_f.ids))
    return {
        "site": "flat_chunk_select", "Q": qn, "C": c, "k": k,
        "mode": ops.get_mode(),
        "unfused_ms": t_u * 1e3, "fused_ms": t_f * 1e3,
        "speedup": t_u / t_f,
        "sort_width_unfused": k + c, "sort_width_fused": 2 * k,
        "hbm_bytes_unfused": qn * (k + c) * 8,
        "hbm_bytes_fused": qn * 2 * k * 8,
        "exact": True,
    }


def _bench_dtw_panel(n_series, length, n_queries, r):
    from repro.kernels.dtw_band import dtw_band_panel
    from repro.kernels import ref
    q, _, x, _, _, _ = _panel_inputs(n_series, length, n_queries, seed=9)
    scan = jax.jit(lambda: ref.dtw_band_ref(q[:, None, :], x[None], r))
    kern = functools.partial(dtw_band_panel, q, x, r=r, interpret=True)
    t_scan, d_scan = timeit(scan)
    t_kern, d_kern = timeit(kern, warmup=1, iters=1)
    assert np.array_equal(np.asarray(d_scan), np.asarray(d_kern)), \
        "DTW wavefront kernel must be bit-identical to the scan"
    return {
        "site": "dtw_band_panel", "Q": q.shape[0], "C": x.shape[0],
        "k": "-", "mode": "interpret-vs-ref",
        "unfused_ms": t_scan * 1e3, "fused_ms": t_kern * 1e3,
        "speedup": t_scan / t_kern,
        "sort_width_unfused": "-", "sort_width_fused": "-",
        "hbm_bytes_unfused": 3 * q.shape[0] * x.shape[0] * length * 4,
        "hbm_bytes_fused": q.shape[0] * x.shape[0] * 4,
        "exact": True,
    }


def _bench_dtw_flat_cell(n_series, length, n_queries, k, r):
    raw = jnp.asarray(make_dataset("synthetic", n_series, length))
    rng = np.random.default_rng(11)
    qs = jnp.asarray(np.asarray(raw[rng.choice(n_series, n_queries,
                                               replace=False)])
                     + 0.05 * rng.standard_normal(
                         (n_queries, length)).astype(np.float32))
    idx = core.build(raw, capacity=min(256, n_series))
    fidx = core.build_flat(raw)
    t_qm, r_qm = timeit(D.search_dtw, idx, qs, r=r, k=k, iters=2)
    t_fl, r_fl = timeit(D.search_dtw_flat, fidx, qs, r=r, k=k,
                        block_index=idx, iters=2)
    assert np.array_equal(np.asarray(r_qm.idx), np.asarray(r_fl.idx)), \
        "DTW x flat must return the query-major cell's exact ids"
    np.testing.assert_allclose(np.asarray(r_qm.dist), np.asarray(r_fl.dist),
                               rtol=1e-5, atol=1e-5)
    return {
        "site": "dtw_x_flat_driver", "Q": n_queries, "C": n_series, "k": k,
        "mode": ops.get_mode(),
        "unfused_ms": t_qm * 1e3, "fused_ms": t_fl * 1e3,
        "speedup": t_qm / t_fl,
        "sort_width_unfused": "-", "sort_width_fused": 2 * k,
        "hbm_bytes_unfused": "-", "hbm_bytes_fused": "-",
        "exact": True,
    }


def run(n_series: int = 8192, length: int = 128, n_queries: int = 16,
        k: int = 10, r: int = 6, dtw_series: int = 512,
        dtw_flat_series: int = 2048) -> list[dict]:
    rows = [
        _bench_panel_refine(n_series, length, n_queries, k),
        _bench_flat_select(n_series, length, n_queries, k),
        _bench_dtw_panel(dtw_series, 64, min(4, n_queries), r),
        _bench_dtw_flat_cell(dtw_flat_series, 64, min(4, n_queries), k, r),
    ]
    print_table("fused refine kernels (DESIGN.md SS8)", rows,
                ["site", "Q", "C", "k", "mode", "unfused_ms", "fused_ms",
                 "speedup", "sort_width_unfused", "sort_width_fused",
                 "hbm_bytes_unfused", "hbm_bytes_fused", "exact"])
    write_rows("kernels", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=8192)
            .arg("--length", type=int, default=128)
            .arg("--queries", type=int, default=16)
            .arg("--k", type=int, default=10)
            .arg("--band", type=int, default=6)
            .arg("--dtw-size", type=int, default=512)
            .arg("--dtw-flat-size", type=int, default=2048)
            .main(lambda a: run(n_series=a.size, length=a.length,
                                n_queries=a.queries, k=a.k, r=a.band,
                                dtw_series=a.dtw_size,
                                dtw_flat_series=a.dtw_flat_size), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
