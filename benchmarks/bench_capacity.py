"""Leaf-capacity ablation — the paper's leaf-size knob (max leaf capacity),
which trades pruning granularity (small leaves prune tighter) against
per-visit efficiency (large leaves amortize fetch + MXU panel setup).

Measured for both schedules; the block-major optimum is what
`search_sharded` defaults to.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import (BenchRunner, csv_ints, print_table,
                               timeit, write_rows)
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.data import make_dataset


def run(n: int = 100_000, capacities=(128, 256, 512, 1024, 2048),
        n_queries: int = 16) -> list[dict]:
    raw_np = make_dataset("synthetic", n, 256)
    raw = jnp.asarray(raw_np)
    rng = np.random.default_rng(5)
    qs = jnp.asarray(raw_np[rng.choice(n, n_queries, replace=False)]
                     + 0.05 * rng.standard_normal((n_queries, 256))
                     .astype(np.float32))
    oracle = search_scan(raw, qs)
    rows = []
    for cap in capacities:
        idx = core.build(raw, capacity=cap)
        t_qm, r_qm = timeit(core.search, idx, qs, iters=2)
        t_bm, r_bm = timeit(search_block_major, idx, qs, iters=2)
        assert np.array_equal(np.asarray(r_bm.idx), np.asarray(oracle.idx))
        rows.append({
            "capacity": cap, "blocks": int(idx.n_blocks),
            "query_major_ms": t_qm / n_queries * 1e3,
            "block_major_ms": t_bm / n_queries * 1e3,
            "bm_refined_frac": float(np.mean(np.asarray(
                r_bm.stats.series_refined))) / n,
            "bm_blocks_visited": float(np.mean(np.asarray(
                r_bm.stats.blocks_visited))),
        })
    print_table("leaf capacity ablation", rows,
                ["capacity", "blocks", "query_major_ms", "block_major_ms",
                 "bm_refined_frac", "bm_blocks_visited"])
    write_rows("capacity", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=100_000)
            .arg("--capacities", type=csv_ints,
                 default=(128, 256, 512, 1024, 2048))
            .arg("--queries", type=int, default=16)
            .main(lambda a: run(n=a.size, capacities=a.capacities,
                                n_queries=a.queries), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
