"""Serving benchmark: the block-cache SearchSession, cold vs warm —
plus latency-under-concurrency for the multi-tenant coalescer.

The paper's serving claim is two-sided — seconds from disk (ParIS+),
milliseconds from memory (MESSI).  A serving process with repeated
traffic sits between the two: `storage.SearchSession` keeps an LRU of
device-resident raw blocks across query batches, so the surviving
working set migrates on device and warm batches approach the in-memory
latency without ever holding more than `cache_blocks` raw blocks.

Three sections, one BENCH_serve.json:

  * cold-vs-warm (``mode == "session"``): a fixed sequence of query
    batches answered twice through one session per cache size —
    per-batch p50/p99 latency, warm-pass hit-rate, disk bytes per pass;
    sweeping `--cache-blocks` gives hit-rate (and latency) vs size.
  * concurrency (``mode in {"isolated", "coalesced"}``): N tenants
    submit together and are answered either by N serial isolated
    sessions or by one coalesced ``submit``/``drain`` — per-tenant
    completion-latency p50/p99, fairness (max/mean completion), and
    disk blocks (sum vs union).  Exactness between the two modes is
    asserted bitwise before anything is reported.
  * pipeline sweep (``mode == "pipeline"``): the depth-D / group-G
    walk pipeline on a COLD cache per point — per-query latency,
    host<->device threshold syncs per walked block (the amortization:
    syncs ~= walked/G + 1), blocks speculated-but-pruned
    (fetched + hits - refined), and reader-pool effectiveness
    (1 - demand-miss fraction: how many disk reads the speculation
    hid from the walk).  Answers are asserted bitwise against the
    serial (D=1, G=1) walk before any number is reported.

    PYTHONPATH=src python -m benchmarks.bench_serve \\
        --size 50000 --cache-blocks 8,32,128 --tenants 2,4,8 \\
        --depths 1,2,4 --groups 1,2,8 --out BENCH_serve.json
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRunner, csv_ints, print_table, write_rows
from repro import storage
from repro.analysis import sanitize
from repro.data import make_dataset


def _serve_pass(session, batches, k: int):
    """Answer every batch once; -> (per-batch ms, results, fetched, hits)."""
    f0, h0 = session.blocks_fetched, session.cache_hits
    lat, results = [], []
    for qs in batches:
        t0 = time.perf_counter()
        res = session.search(qs, k=k)
        jax.block_until_ready(res.dist)
        lat.append((time.perf_counter() - t0) * 1e3)
        results.append(res)
    return (np.asarray(lat), results,
            session.blocks_fetched - f0, session.cache_hits - h0)


def _concurrency_section(opened, batches, k: int, cache_blocks: int,
                         tenants) -> list[dict]:
    """N tenants, isolated-serial vs coalesced: completion latency,
    fairness, and disk blocks.  Asserts bitwise exactness first."""
    rows = []
    for nt in tenants:
        nt = min(nt, len(batches))
        load = batches[:nt]

        # compile warmup for the merged (sum-of-tenants, n) panel shape
        # on a throwaway session — same-plan tickets coalesce into one
        # device panel, a shape the per-tenant passes never traced; the
        # measured drain below is steady-state serving, cold on disk only
        with storage.SearchSession(opened, cache_blocks=2) as wu:
            for qs in load:
                wu.submit(qs, k=k)
            jax.block_until_ready(wu.drain()[0].result().dist)

        # isolated: each tenant a fresh session, answered back to back
        # (the no-subsystem baseline); tenant i's completion latency
        # includes the queueing behind tenants 0..i-1
        iso_res, iso_done, iso_fetched = [], [], 0
        t0 = time.perf_counter()
        for qs in load:
            with storage.SearchSession(opened,
                                       cache_blocks=cache_blocks) as s:
                r = s.search(qs, k=k)
                jax.block_until_ready(r.dist)
                iso_res.append(r)
                iso_done.append((time.perf_counter() - t0) * 1e3)
                iso_fetched += s.blocks_fetched

        # coalesced: every tenant admitted, then ONE drain answers all —
        # completion latency is the shared drain (plus queue position 0)
        with storage.SearchSession(opened,
                                   cache_blocks=cache_blocks) as sess:
            tickets = [sess.submit(qs, k=k) for qs in load]
            t0 = time.perf_counter()
            sess.drain()
            co_res = [t.result() for t in tickets]
            jax.block_until_ready(co_res[-1].dist)
            drain_ms = (time.perf_counter() - t0) * 1e3
            co_fetched = sess.blocks_fetched
        co_done = [drain_ms] * nt

        for a, b in zip(iso_res, co_res):              # exactness first
            assert np.array_equal(np.asarray(a.idx),
                                  np.asarray(b.idx)), "exactness!"
            assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist))

        for mode, done, fetched in (("isolated", iso_done, iso_fetched),
                                    ("coalesced", co_done, co_fetched)):
            done = np.asarray(done)
            rows.append({
                "mode": mode, "tenants": nt, "k": k,
                "queries_per_tenant": int(load[0].shape[0]),
                "cache_blocks": cache_blocks,
                "p50_ms": float(np.percentile(done, 50)),
                "p99_ms": float(np.percentile(done, 99)),
                "makespan_ms": float(done.max()),
                # 1.0 = perfectly fair (everyone finishes together);
                # serial isolation degrades toward ~2x at large N
                "fairness": float(done.max() / max(done.mean(), 1e-9)),
                "blocks_fetched": int(fetched),
            })
    return rows


def _pipeline_section(opened, batches, k: int, cache_blocks: int,
                      depths, groups, readers: int) -> list[dict]:
    """Depth x group sweep, every point cold on disk: each batch runs
    through a FRESH session, so the latency is the overlap the pipeline
    wins against real (first-touch) reads, not cache residency.
    Exactness vs the serial walk is asserted before reporting."""
    serial = None
    rows = []
    for d in depths:
        for g in groups:
            lat, tel_sum, io_sum, misses, results = [], {}, {}, 0, []
            for qs in batches:
                with storage.SearchSession(
                        opened, cache_blocks=max(cache_blocks, d + g),
                        readers=readers, pipeline_depth=d,
                        group_blocks=g) as sess:
                    t0 = time.perf_counter()
                    res = sess.search(qs, k=k)
                    jax.block_until_ready(res.dist)
                    lat.append((time.perf_counter() - t0) * 1e3)
                    misses += sess.cache.demand_misses
                    for key, v in sess.last_telemetry.items():
                        tel_sum[key] = tel_sum.get(key, 0) + v
                results.append(res)
                for key in ("blocks_fetched", "cache_hits",
                            "blocks_refined"):
                    io_sum[key] = io_sum.get(key, 0) + getattr(res.io, key)
            if serial is None:
                serial = results             # (depths, groups) start at 1, 1
            for a, b in zip(results, serial):          # exactness first
                assert np.array_equal(np.asarray(a.idx),
                                      np.asarray(b.idx)), "exactness!"
                assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist))
            lat = np.asarray(lat)
            walked = tel_sum["walk_blocks"]
            touched = io_sum["blocks_fetched"] + io_sum["cache_hits"]
            rows.append({
                "mode": "pipeline", "pipeline_depth": d, "group_blocks": g,
                "readers": readers, "k": k,
                "cache_blocks": max(cache_blocks, d + g),
                "p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99)),
                "ms_per_query": float(np.percentile(lat, 50)
                                      / batches[0].shape[0]),
                "syncs": int(tel_sum["syncs"]),
                "walk_blocks": int(walked),
                "syncs_per_block": tel_sum["syncs"] / max(walked, 1),
                "speculated_pruned": int(touched - io_sum["blocks_refined"]),
                "demand_miss_frac": misses / max(io_sum["blocks_fetched"], 1),
            })
    # the acceptance property: grouping amortizes the per-block sync
    # (syncs ~= walked/G + 1 per batch; compare same-depth rows)
    by_dg = {(r["pipeline_depth"], r["group_blocks"]): r for r in rows}
    for d in depths:
        base = by_dg.get((d, 1))
        for g in groups:
            r = by_dg[(d, g)]
            if base is not None and g > 1:
                assert r["syncs"] < base["syncs"], \
                    f"group_blocks={g} did not amortize syncs"
            assert r["syncs"] <= r["walk_blocks"] / g + 2 * len(batches), \
                "syncs exceed the walked/G bound"
    return rows


def run(n: int = 50_000, length: int = 256, n_queries: int = 8,
        n_batches: int = 6, capacity: int = 1024,
        cache_blocks=(8, 32, 128), k: int = 5, tenants=(2, 4),
        depths=(1, 2, 4), groups=(1, 2, 8), readers: int = 3,
        workdir: str | None = None) -> list[dict]:
    tmp = workdir or tempfile.mkdtemp(prefix="bench_serve_")
    raw = make_dataset("synthetic", n, length)
    rng = np.random.default_rng(99)
    batches = [jnp.asarray(raw[rng.choice(n, n_queries, replace=False)]
                           + 0.05 * rng.standard_normal((n_queries, length))
                           .astype(np.float32))
               for _ in range(n_batches)]

    series_path = os.path.join(tmp, f"serve_{n}.f32")
    index_path = os.path.join(tmp, f"serve_{n}.dsix")
    store = storage.SeriesStore.write(series_path, raw)
    opened = storage.build_on_disk(store, index_path, capacity=capacity)

    # compile warmup on a throwaway session: the jit cache is global but
    # the block cache is per-session, so the measured cold pass stays cold
    with storage.SearchSession(opened, cache_blocks=2) as warmup:
        jax.block_until_ready(warmup.search(batches[0], k=k).dist)

    rows = []
    for cb in cache_blocks:
        cb = max(2, min(cb, opened.n_blocks))   # 2 = BlockCache floor
        with storage.SearchSession(opened, cache_blocks=cb) as sess:
            cold, cold_res, cold_fetch, _ = _serve_pass(sess, batches, k)
            warm, warm_res, warm_fetch, warm_hits = _serve_pass(
                sess, batches, k)
        for a, b in zip(cold_res, warm_res):           # caching never
            assert np.array_equal(np.asarray(a.idx),   # changes answers
                                  np.asarray(b.idx)), "exactness!"
            assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist))
        rows.append({
            "mode": "session",
            "n_series": n, "k": k, "n_batches": n_batches,
            "queries_per_batch": n_queries,
            "cache_blocks": cb, "blocks_total": opened.n_blocks,
            "cold_p50_ms": float(np.percentile(cold, 50)),
            "cold_p99_ms": float(np.percentile(cold, 99)),
            "warm_p50_ms": float(np.percentile(warm, 50)),
            "warm_p99_ms": float(np.percentile(warm, 99)),
            "warm_speedup": float(np.percentile(cold, 50)
                                  / max(np.percentile(warm, 50), 1e-9)),
            "warm_hit_rate": warm_hits / max(warm_hits + warm_fetch, 1),
            "cold_blocks_fetched": cold_fetch,
            "warm_blocks_fetched": warm_fetch,
            "cold_mb_read": cold_fetch * opened.host_raw.block_nbytes / 2**20,
            "warm_mb_read": warm_fetch * opened.host_raw.block_nbytes / 2**20,
        })
    conc_cb = max(2, min(max(cache_blocks), opened.n_blocks))
    conc_rows = _concurrency_section(opened, batches, k, conc_cb, tenants)
    pipe_rows = _pipeline_section(opened, batches, k, conc_cb,
                                  depths, groups, readers)

    os.remove(series_path)
    os.remove(index_path)
    print_table("serving sessions: cold vs warm through the block cache",
                rows, ["n_series", "k", "cache_blocks", "blocks_total",
                       "cold_p50_ms", "warm_p50_ms", "warm_speedup",
                       "warm_hit_rate", "cold_mb_read", "warm_mb_read"])
    print_table("concurrency: N isolated sessions vs one coalesced drain",
                conc_rows, ["mode", "tenants", "cache_blocks", "p50_ms",
                            "p99_ms", "makespan_ms", "fairness",
                            "blocks_fetched"])
    print_table("pipeline sweep: depth-D prefetch x group-G refine "
                "(cold cache; exactness asserted)",
                pipe_rows, ["pipeline_depth", "group_blocks", "readers",
                            "p50_ms", "ms_per_query", "syncs",
                            "walk_blocks", "syncs_per_block",
                            "speculated_pruned", "demand_miss_frac"])
    rows += conc_rows + pipe_rows
    # meta row first, so readers can tell the numbers came from
    # uninstrumented locks (run.py refuses to run when sanitizing)
    rows.insert(0, {"mode": "meta", "sanitize": sanitize.enabled()})
    write_rows("serve", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--size", type=int, default=50_000)
            .arg("--length", type=int, default=256)
            .arg("--queries", type=int, default=8)
            .arg("--batches", type=int, default=6)
            .arg("--capacity", type=int, default=1024)
            .arg("--cache-blocks", type=csv_ints, default=(8, 32, 128))
            .arg("--k", type=int, default=5)
            .arg("--tenants", type=csv_ints, default=(2, 4))
            .arg("--depths", type=csv_ints, default=(1, 2, 4))
            .arg("--groups", type=csv_ints, default=(1, 2, 8))
            .arg("--readers", type=int, default=3)
            .main(lambda a: run(n=a.size, length=a.length,
                                n_queries=a.queries, n_batches=a.batches,
                                capacity=a.capacity,
                                cache_blocks=a.cache_blocks, k=a.k,
                                tenants=a.tenants, depths=a.depths,
                                groups=a.groups, readers=a.readers), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
