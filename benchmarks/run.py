"""Run every benchmark family (one per paper figure group) and summarize.

    PYTHONPATH=src python -m benchmarks.run            # full set
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import (bench_build, bench_capacity, bench_dtw,
                            bench_engine, bench_ooc, bench_query,
                            bench_scaling, bench_serve)

    t0 = time.time()
    if args.quick:
        bench_build.run(sizes=(20_000,), datasets=("synthetic",))
        bench_query.run(sizes=(50_000,), datasets=("synthetic",))
        bench_engine.run(n=10_000, capacity=256)
        bench_ooc.run(sizes=(20_000,), datasets=("synthetic",),
                      capacity=256, ks=(1, 5))
        bench_serve.run(n=20_000, n_queries=4, n_batches=4, capacity=256,
                        cache_blocks=(8, 96))
        bench_dtw.run(n=5_000)
        bench_capacity.run(n=50_000, capacities=(256, 1024))
        bench_scaling.run(device_counts=(1, 4))
    else:
        bench_build.run()
        bench_query.run()
        bench_engine.run()
        bench_ooc.run()
        bench_serve.run()
        bench_dtw.run()
        bench_capacity.run()
        bench_scaling.run()
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; "
          f"JSON in experiments/bench/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
