"""Run every benchmark family (one per paper figure group) and summarize.

    PYTHONPATH=src python -m benchmarks.run            # full set
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes

Each family's rows land twice: in ``experiments/bench/<name>.json``
(the drivers' own output) and as root-level ``BENCH_<name>.json`` in
the current directory — the same artifact names CI uploads — so a local
``--quick`` run leaves a comparable perf trajectory behind instead of
nothing (pass ``--no-artifacts`` to skip the root-level copies).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="skip writing root-level BENCH_*.json copies")
    args = ap.parse_args(argv)

    from repro.analysis import sanitize
    if sanitize.enabled():
        raise SystemExit(
            "benchmarks refuse to run with REPRO_SANITIZE=1: instrumented "
            "locks would be measured instead of the production ones")

    from benchmarks import (bench_build, bench_capacity, bench_dtw,
                            bench_engine, bench_kernels, bench_ooc,
                            bench_query, bench_scaling, bench_serve)

    quick_kwargs = {
        "build": dict(sizes=(20_000,), datasets=("synthetic",),
                      pipeline_n=20_000, pipeline_workers=(1, 2)),
        "query": dict(sizes=(50_000,), datasets=("synthetic",)),
        "engine": dict(n=10_000, capacity=256),
        "ooc": dict(sizes=(20_000,), datasets=("synthetic",),
                    capacity=256, ks=(1, 5)),
        "serve": dict(n=20_000, n_queries=4, n_batches=4, capacity=256,
                      cache_blocks=(8, 96), tenants=(2, 4)),
        "dtw": dict(n=5_000),
        "kernels": dict(n_series=2048, n_queries=8, dtw_series=128,
                        dtw_flat_series=512),
        "capacity": dict(n=50_000, capacities=(256, 1024)),
        "scaling": dict(device_counts=(1, 4)),
    }
    families = [
        ("build", bench_build.run), ("query", bench_query.run),
        ("engine", bench_engine.run), ("ooc", bench_ooc.run),
        ("serve", bench_serve.run), ("dtw", bench_dtw.run),
        ("kernels", bench_kernels.run),
        ("capacity", bench_capacity.run), ("scaling", bench_scaling.run),
    ]

    t0 = time.time()
    for name, run in families:
        rows = run(**(quick_kwargs[name] if args.quick else {}))
        if not args.no_artifacts:
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"wrote {path}")
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; "
          f"JSON in experiments/bench/ and BENCH_*.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
