"""Index-creation benchmarks — the paper's Fig. 4/5/6/7 family.

Measures, per dataset (Synthetic / SALD-like / Seismic-like) and size:
  * serial      — chunked build, each chunk staged + summarized
                  synchronously (the ADS+-style non-overlapped baseline);
  * paris_plus  — ChunkedLoader double buffering + async dispatch
                  (ingest/compute overlap — the ParIS+ mechanism);
  * messi       — one-shot in-memory build (MESSI stage 1+2).

On one CPU device the paper's #cores axis becomes the shard-partition axis
of the distributed builder (bench_scaling.py); here we report wall time and
the overlap gain serial -> paris_plus, which is the paper's Fig. 4 claim
("ParIS+ completely masks the CPU cost") in this container's terms.

The ``pipeline`` section benchmarks the staged on-disk build
(storage/pipeline/): wall time vs pass-1/pass-2 worker count, and the
resume overhead after an injected mid-permute kill — with byte-exactness
against ``save_index(core.build(...))`` asserted BEFORE any timing, so a
fast-but-wrong pipeline can never post a number.
"""
from __future__ import annotations

import hashlib
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import (BenchRunner, csv_ints, csv_strs, print_table,
                               timeit, write_rows)
from repro import storage
from repro.data import make_dataset
from repro.data.loader import ChunkedLoader, IncrementalBuilder
from repro.storage.pipeline import BuildInterrupted, run_pipeline


def build_serial(raw: np.ndarray, capacity: int):
    builder = IncrementalBuilder(capacity=capacity)
    for start in range(0, len(raw), 1 << 14):
        chunk = jax.device_put(raw[start:start + (1 << 14)])
        jax.block_until_ready(chunk)                  # no overlap
        builder.add_chunk(chunk)
        jax.block_until_ready(builder._sax[-1])
    return builder.finalize()


def build_overlapped(raw: np.ndarray, capacity: int):
    loader = ChunkedLoader(raw, chunk=1 << 14)
    builder = IncrementalBuilder(capacity=capacity)
    for chunk in loader:                              # staged async
        builder.add_chunk(chunk)                      # dispatched async
    return builder.finalize()


def _sha(path) -> str:
    return hashlib.sha256(Path(path).read_bytes()).hexdigest()


def run_pipeline_section(n: int = 100_000, length: int = 128,
                         capacity: int = 512, chunk: int = 1 << 13,
                         worker_counts=(1, 2, 4)) -> list[dict]:
    """Staged-build rows: throughput vs workers + kill/resume overhead."""
    raw = make_dataset("synthetic", n, length)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        store = storage.SeriesStore.write(td / "series.f32", raw)

        # exactness FIRST: byte-identity to the in-memory write path
        golden = td / "golden.dsix"
        storage.save_index(core.build(jnp.asarray(raw), capacity=capacity),
                           golden)
        probe = td / "probe.dsix"
        run_pipeline(store, probe, capacity=capacity, chunk=chunk,
                     workers=2, shards=4)
        assert _sha(probe) == _sha(golden), \
            "pipeline output diverged from save_index(core.build(...))"

        t_by_workers = {}
        for wk in worker_counts:
            out = td / f"w{wk}.dsix"
            t0 = time.perf_counter()
            _, rep = run_pipeline(store, out, capacity=capacity, chunk=chunk,
                                  workers=wk, shards=max(worker_counts))
            t = time.perf_counter() - t0
            t_by_workers[wk] = t
            rows.append({
                "mode": "pipeline", "workers": wk, "n_series": n,
                "length": length, "build_s": t,
                "throughput_Mseries_s": n / t / 1e6,
                "speedup_vs_1": t_by_workers[worker_counts[0]] / t,
            })

        # resume overhead: kill after the first completed permute unit,
        # then resume; overhead = extra wall vs one uninterrupted build
        def fault(stage, done):
            if stage == "permute" and done >= 1:
                raise BuildInterrupted(f"{stage}:{done}")

        out = td / "killed.dsix"
        t0 = time.perf_counter()
        try:
            run_pipeline(store, out, capacity=capacity, chunk=chunk,
                         shards=max(worker_counts), fault=fault)
        except BuildInterrupted:
            pass
        t_interrupted = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, rep = run_pipeline(store, out, capacity=capacity, chunk=chunk,
                              shards=max(worker_counts))
        t_resume = time.perf_counter() - t0
        assert rep.resumed and _sha(out) == _sha(golden)
        fresh = t_by_workers[worker_counts[0]]
        rows.append({
            "mode": "pipeline_resume", "workers": 1, "n_series": n,
            "length": length, "interrupted_s": t_interrupted,
            "resume_s": t_resume, "fresh_s": fresh,
            "resume_overhead": (t_interrupted + t_resume) / fresh - 1.0,
            "permute_reused": rep.stages["permute"].reused,
            "permute_built": rep.stages["permute"].built,
        })
    print_table("staged pipeline build (sharded + kill/resume)", rows,
                ["mode", "workers", "n_series", "build_s",
                 "throughput_Mseries_s", "resume_s", "resume_overhead",
                 "permute_reused"])
    return rows


def run(sizes=(50_000, 200_000), datasets=("synthetic", "sald", "seismic"),
        capacity: int = 1024, pipeline_n: int = 100_000,
        pipeline_workers=(1, 2, 4)) -> list[dict]:
    rows = []
    for ds in datasets:
        for n in sizes:
            length = 128 if ds == "sald" else 256
            raw = make_dataset(ds, n, length)
            t_serial, _ = timeit(build_serial, raw, capacity, iters=2)
            t_overlap, _ = timeit(build_overlapped, raw, capacity, iters=2)
            t_messi, idx = timeit(
                lambda r: core.build(jnp.asarray(r), capacity=capacity),
                raw, iters=2)
            rows.append({
                "dataset": ds, "n_series": n, "length": length,
                "serial_s": t_serial, "paris_plus_s": t_overlap,
                "messi_s": t_messi,
                "overlap_gain": t_serial / t_overlap,
                "throughput_Mseries_s": n / t_messi / 1e6,
                "blocks": int(idx.n_blocks),
            })
    print_table("index build (Fig. 4-7)", rows,
                ["dataset", "n_series", "serial_s", "paris_plus_s",
                 "messi_s", "overlap_gain", "throughput_Mseries_s"])
    if pipeline_n:
        rows += run_pipeline_section(n=pipeline_n,
                                     worker_counts=pipeline_workers)
    write_rows("build", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--sizes", type=csv_ints, default=(50_000, 200_000))
            .arg("--datasets", type=csv_strs,
                 default=("synthetic", "sald", "seismic"))
            .arg("--capacity", type=int, default=1024)
            .arg("--pipeline-n", type=int, default=100_000,
                 help="series count for the staged-pipeline section "
                      "(0 disables it)")
            .arg("--pipeline-workers", type=csv_ints, default=(1, 2, 4))
            .main(lambda a: run(sizes=a.sizes, datasets=a.datasets,
                                capacity=a.capacity, pipeline_n=a.pipeline_n,
                                pipeline_workers=a.pipeline_workers), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
