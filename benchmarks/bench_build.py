"""Index-creation benchmarks — the paper's Fig. 4/5/6/7 family.

Measures, per dataset (Synthetic / SALD-like / Seismic-like) and size:
  * serial      — chunked build, each chunk staged + summarized
                  synchronously (the ADS+-style non-overlapped baseline);
  * paris_plus  — ChunkedLoader double buffering + async dispatch
                  (ingest/compute overlap — the ParIS+ mechanism);
  * messi       — one-shot in-memory build (MESSI stage 1+2).

On one CPU device the paper's #cores axis becomes the shard-partition axis
of the distributed builder (bench_scaling.py); here we report wall time and
the overlap gain serial -> paris_plus, which is the paper's Fig. 4 claim
("ParIS+ completely masks the CPU cost") in this container's terms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import (BenchRunner, csv_ints, csv_strs, print_table,
                               timeit, write_rows)
from repro.data import make_dataset
from repro.data.loader import ChunkedLoader, IncrementalBuilder


def build_serial(raw: np.ndarray, capacity: int):
    builder = IncrementalBuilder(capacity=capacity)
    for start in range(0, len(raw), 1 << 14):
        chunk = jax.device_put(raw[start:start + (1 << 14)])
        jax.block_until_ready(chunk)                  # no overlap
        builder.add_chunk(chunk)
        jax.block_until_ready(builder._sax[-1])
    return builder.finalize()


def build_overlapped(raw: np.ndarray, capacity: int):
    loader = ChunkedLoader(raw, chunk=1 << 14)
    builder = IncrementalBuilder(capacity=capacity)
    for chunk in loader:                              # staged async
        builder.add_chunk(chunk)                      # dispatched async
    return builder.finalize()


def run(sizes=(50_000, 200_000), datasets=("synthetic", "sald", "seismic"),
        capacity: int = 1024) -> list[dict]:
    rows = []
    for ds in datasets:
        for n in sizes:
            length = 128 if ds == "sald" else 256
            raw = make_dataset(ds, n, length)
            t_serial, _ = timeit(build_serial, raw, capacity, iters=2)
            t_overlap, _ = timeit(build_overlapped, raw, capacity, iters=2)
            t_messi, idx = timeit(
                lambda r: core.build(jnp.asarray(r), capacity=capacity),
                raw, iters=2)
            rows.append({
                "dataset": ds, "n_series": n, "length": length,
                "serial_s": t_serial, "paris_plus_s": t_overlap,
                "messi_s": t_messi,
                "overlap_gain": t_serial / t_overlap,
                "throughput_Mseries_s": n / t_messi / 1e6,
                "blocks": int(idx.n_blocks),
            })
    print_table("index build (Fig. 4-7)", rows,
                ["dataset", "n_series", "serial_s", "paris_plus_s",
                 "messi_s", "overlap_gain", "throughput_Mseries_s"])
    write_rows("build", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--sizes", type=csv_ints, default=(50_000, 200_000))
            .arg("--datasets", type=csv_strs,
                 default=("synthetic", "sald", "seismic"))
            .arg("--capacity", type=int, default=1024)
            .main(lambda a: run(sizes=a.sizes, datasets=a.datasets,
                                capacity=a.capacity), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
