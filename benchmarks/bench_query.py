"""Query-answering benchmarks — the paper's Fig. 8/9/10/11/12 family.

Exact k-NN latency of the three systems on the three datasets:
  UCR-Suite-p  (brute-force MXU scan)      — paper's serial-scan baseline
  ParIS        (flat SAX lower-bound scan) — paper's on-disk index, in-mem
  MESSI        (ordered block pruning)     — paper's in-memory index

plus the work statistics that explain the ratios (lower bounds computed,
real distances computed — the paper's §IV mechanism discussion).  The
paper's headline ratios to compare against: MESSI 55-80x faster than
UCR-p, 6.4-11x faster than ParIS.

The ``--k`` sweep records the recall-free cost of larger result lists
(the frontier insert grows as K + chunk; pruning loosens as the k-th
best distance rises) — the recall/latency trade-off axis of
EXPERIMENTS.md §Perf:

    PYTHONPATH=src python -m benchmarks.bench_query \\
        --sizes 100000 --datasets synthetic --k 1,5,32 --out BENCH_query.json
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import (BenchRunner, csv_ints, csv_strs, print_table,
                               timeit, write_rows)
from repro.core.paris import search_paris
from repro.core.search import search_block_major
from repro.core.ucr import search_scan
from repro.data import make_dataset


def run(sizes=(100_000, 400_000), datasets=("synthetic", "sald", "seismic"),
        n_queries: int = 16, capacity: int = 1024,
        ks=(1,)) -> list[dict]:
    rows = []
    for ds in datasets:
        for n in sizes:
            length = 128 if ds == "sald" else 256
            raw = make_dataset(ds, n, length)
            rng = np.random.default_rng(99)
            qs = jnp.asarray(
                raw[rng.choice(n, n_queries, replace=False)]
                + 0.05 * rng.standard_normal((n_queries, length))
                .astype(np.float32))
            raw_j = jnp.asarray(raw)
            idx = core.build(raw_j, capacity=capacity)

            for k in ks:
                t_ucr, r_ucr = timeit(search_scan, raw_j, qs, k=k)
                t_paris, r_paris = timeit(search_paris, idx, qs, k=k)
                t_messi, r_messi = timeit(core.search, idx, qs, k=k)
                t_bm, r_bm = timeit(search_block_major, idx, qs, k=k)

                assert np.array_equal(np.asarray(r_messi.idx),
                                      np.asarray(r_ucr.idx)), "exactness!"
                assert np.array_equal(np.asarray(r_bm.idx),
                                      np.asarray(r_ucr.idx)), "exactness (bm)!"
                assert np.array_equal(np.asarray(r_paris.idx),
                                      np.asarray(r_ucr.idx)), "exactness (paris)!"
                per_q = lambda t: t / n_queries * 1e3
                rows.append({
                    "dataset": ds, "n_series": n, "k": k,
                    "ucr_ms": per_q(t_ucr), "paris_ms": per_q(t_paris),
                    "messi_ms": per_q(t_messi),
                    "messi_bm_ms": per_q(t_bm),
                    "messi_vs_ucr": t_ucr / t_messi,
                    "messi_bm_vs_ucr": t_ucr / t_bm,
                    "messi_vs_paris": t_paris / t_messi,
                    "paris_vs_ucr": t_ucr / t_paris,
                    "refined_frac_messi": float(np.mean(np.asarray(
                        r_messi.stats.series_refined))) / n,
                    "refined_frac_paris": float(np.mean(np.asarray(
                        r_paris.stats.series_refined))) / n,
                    "lb_frac_messi": float(np.mean(np.asarray(
                        r_messi.stats.lb_series))) / n,
                })
    print_table("query answering (Fig. 8-12)", rows,
                ["dataset", "n_series", "k", "ucr_ms", "paris_ms", "messi_ms",
                 "messi_bm_ms", "messi_vs_ucr", "messi_bm_vs_ucr",
                 "refined_frac_messi", "refined_frac_paris"])
    write_rows("query", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--sizes", type=csv_ints, default=(100_000, 400_000),
                 help="comma-separated dataset sizes")
            .arg("--datasets", type=csv_strs,
                 default=("synthetic", "sald", "seismic"))
            .arg("--k", type=csv_ints, default=(1,),
                 help="comma-separated k sweep, e.g. 1,5,32")
            .arg("--queries", type=int, default=16)
            .arg("--capacity", type=int, default=1024)
            .main(lambda a: run(sizes=a.sizes, datasets=a.datasets,
                                n_queries=a.queries, capacity=a.capacity,
                                ks=a.k), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
