"""Out-of-core benchmarks — the paper's headline on-disk claim.

"Our on-disk solution can answer exact similarity search queries on 100GB
datasets in a few seconds, and our in-memory solution in a few
milliseconds": this driver measures the repo's version of that two-sided
claim at configurable sizes —

  * two-pass out-of-core build (file -> index file, bounded host memory)
    vs the in-memory build;
  * streaming exact k-NN (`storage.ooc_search`, summaries-resident) vs
    the in-memory MESSI search on identical data;
  * raw bytes read vs a full scan — the bytes-level pruning ratio that
    explains the on-disk latency (the paper's §IV mechanism);
  * depth x group pipeline sweep (``section == "pipeline"``): the same
    one-shot search with D speculative reads in flight and G blocks per
    batched refine — per-query latency, speculated-but-pruned blocks,
    and the threshold-sync amortization, each point cold on disk
    (``ooc_search`` is a throwaway session) and asserted bitwise
    against the serial walk first.

    PYTHONPATH=src python -m benchmarks.bench_ooc \\
        --sizes 50000 --k 1,5 --depths 1,2,4 --groups 1,2,8 \\
        --out BENCH_ooc.json
"""
from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import (BenchRunner, csv_ints, csv_strs, print_table,
                               timeit, write_rows)
from repro import storage
from repro.data import make_dataset


def _pipeline_sweep(opened, qs, k: int, ds: str, n: int,
                    depths, groups, readers: int) -> list[dict]:
    """Cold depth x group sweep through one-shot ``ooc_search`` calls;
    exactness vs the serial point is asserted before reporting."""
    rows, serial = [], None
    for d in depths:
        for g in groups:
            t, r = timeit(storage.ooc_search, opened, qs, k=k,
                          pipeline_depth=d, group_blocks=g,
                          readers=readers)
            if serial is None:
                serial = r                  # (depths, groups) start at 1, 1
            assert np.array_equal(np.asarray(r.idx),
                                  np.asarray(serial.idx)), "exactness!"
            assert np.array_equal(np.asarray(r.dist),
                                  np.asarray(serial.dist)), "exactness!"
            touched = r.io.blocks_fetched + r.io.cache_hits
            rows.append({
                "section": "pipeline", "dataset": ds, "n_series": n,
                "k": k, "pipeline_depth": d, "group_blocks": g,
                "readers": readers,
                "ooc_ms": t / qs.shape[0] * 1e3,
                "blocks_fetched": r.io.blocks_fetched,
                "blocks_refined": r.io.blocks_refined,
                "speculated_pruned": int(touched - r.io.blocks_refined),
            })
    return rows


def run(sizes=(50_000, 200_000), datasets=("synthetic",),
        n_queries: int = 8, capacity: int = 1024, ks=(1, 5),
        depths=(1, 2, 4), groups=(1, 2, 8), readers: int = 3,
        workdir: str | None = None) -> list[dict]:
    rows = []
    pipe_rows: list[dict] = []
    tmp = workdir or tempfile.mkdtemp(prefix="bench_ooc_")
    for ds in datasets:
        for n in sizes:
            length = 128 if ds == "sald" else 256
            raw = make_dataset(ds, n, length)
            rng = np.random.default_rng(99)
            qs = jnp.asarray(
                raw[rng.choice(n, n_queries, replace=False)]
                + 0.05 * rng.standard_normal((n_queries, length))
                .astype(np.float32))

            series_path = os.path.join(tmp, f"{ds}_{n}.f32")
            index_path = os.path.join(tmp, f"{ds}_{n}.dsix")
            store = storage.SeriesStore.write(series_path, raw)

            t_build_mem, idx_mem = timeit(
                lambda: core.build(jnp.asarray(raw), capacity=capacity),
                warmup=0, iters=1)
            t_build_ooc, opened = timeit(
                lambda: storage.build_on_disk(store, index_path,
                                              capacity=capacity),
                warmup=0, iters=1)

            for k in ks:
                t_mem, r_mem = timeit(core.search, idx_mem, qs, k=k)
                t_ooc, r_ooc = timeit(storage.ooc_search, opened, qs, k=k)
                assert np.array_equal(np.asarray(r_ooc.idx),
                                      np.asarray(r_mem.idx)), "exactness!"
                per_q = lambda t: t / n_queries * 1e3
                rows.append({
                    "dataset": ds, "n_series": n, "k": k,
                    "build_mem_s": t_build_mem, "build_ooc_s": t_build_ooc,
                    "mem_ms": per_q(t_mem), "ooc_ms": per_q(t_ooc),
                    "ooc_vs_mem": t_ooc / t_mem,
                    "bytes_read": r_ooc.io.bytes_read,
                    "bytes_scan": r_ooc.io.bytes_scan,
                    "read_frac": r_ooc.io.read_fraction,
                    "blocks_fetched": r_ooc.io.blocks_fetched,
                    "blocks_total": r_ooc.io.blocks_total,
                    "refined_frac": float(np.mean(np.asarray(
                        r_ooc.stats.series_refined))) / n,
                })
            pipe_rows += _pipeline_sweep(opened, qs, max(ks), ds, n,
                                         depths, groups, readers)
            os.remove(series_path)
            os.remove(index_path)
    print_table("out-of-core vs in-memory (paper's on-disk claim)", rows,
                ["dataset", "n_series", "k", "build_mem_s", "build_ooc_s",
                 "mem_ms", "ooc_ms", "ooc_vs_mem", "read_frac",
                 "blocks_fetched", "blocks_total"])
    print_table("pipeline sweep: depth x group, cold one-shot searches",
                pipe_rows, ["dataset", "n_series", "k", "pipeline_depth",
                            "group_blocks", "ooc_ms", "blocks_fetched",
                            "blocks_refined", "speculated_pruned"])
    rows += pipe_rows
    write_rows("ooc", rows)
    return rows


def main(argv=None) -> int:
    return (BenchRunner(__doc__)
            .arg("--sizes", type=csv_ints, default=(50_000, 200_000))
            .arg("--datasets", type=csv_strs, default=("synthetic",))
            .arg("--k", type=csv_ints, default=(1, 5))
            .arg("--queries", type=int, default=8)
            .arg("--capacity", type=int, default=1024)
            .arg("--depths", type=csv_ints, default=(1, 2, 4))
            .arg("--groups", type=csv_ints, default=(1, 2, 8))
            .arg("--readers", type=int, default=3)
            .main(lambda a: run(sizes=a.sizes, datasets=a.datasets,
                                n_queries=a.queries, capacity=a.capacity,
                                ks=a.k, depths=a.depths, groups=a.groups,
                                readers=a.readers), argv))


if __name__ == "__main__":
    import sys
    sys.exit(main())
